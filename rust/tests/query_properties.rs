//! Property tests for the query serving layer (`apsp::query` +
//! `apsp::serve`): every reconstructed path is a real path in the
//! graph whose weight bit-matches `dist(u,v)` (on dyadic weights, so
//! f32 sums are exact and association-independent), the next-hop solve
//! is bit-identical between the scalar oracle and the SIMD-dispatched
//! variant, snapshot reads during a replayed delta script never
//! observe a torn state, and k-nearest agrees with a Dijkstra oracle.
//!
//! All properties run on the seeded harness (`util::prop`); set
//! `RAPID_PROP_SEED` to explore fresh inputs, failures report a replay
//! seed.

use rapid_graph::apsp::delta::{apply_deltas, EdgeDelta};
use rapid_graph::apsp::dijkstra;
use rapid_graph::apsp::query::{self, Query, QueryReq};
use rapid_graph::apsp::serve::{Answer, BatchExec, QuerySnapshot, SnapshotCell};
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// A random graph whose weights are multiples of 0.25 in [0.25, 8]:
/// every shortest-path sum is exactly representable in f32, so the
/// fold order cannot perturb a single bit — "path weight bit-matches
/// dist" is a real equality, not a tolerance band.
fn dyadic_graph(r: &mut Rng) -> CsrGraph {
    let n = 60 + r.gen_range(140);
    let topo = match r.gen_range(3) {
        0 => Topology::Nws,
        1 => Topology::Er,
        _ => Topology::Grid,
    };
    let degree = 4.0 + r.gen_f64() * 6.0;
    let g = generators::generate(topo, n, degree, Weights::Uniform(0.5, 8.0), r.next_u64());
    let edges: Vec<(u32, u32, f32)> = g
        .edges()
        .filter(|&(u, v, _)| u < v)
        .map(|(u, v, w)| (u, v, ((w * 4.0).round() / 4.0).max(0.25)))
        .collect();
    CsrGraph::from_undirected_edges(g.n(), &edges)
}

// -----------------------------------------------------------------
// Path reconstruction: real edges, exact weights
// -----------------------------------------------------------------

#[test]
fn reconstructed_paths_are_real_and_bit_match_dist() {
    assert_prop(
        12,
        |r| (dyadic_graph(r), r.next_u64()),
        |(g, seed)| {
            let mut r = Rng::new(*seed);
            let n = g.n();
            let (dist, next) = query::solve_next_hops(g);
            for _ in 0..64 {
                let (u, v) = (r.gen_range(n), r.gen_range(n));
                let d = dist.get(u, v);
                match next.path(u, v) {
                    None => {
                        if d.is_finite() {
                            return Err(format!(
                                "({u},{v}): no path reconstructed but dist = {d}"
                            ));
                        }
                    }
                    Some(hops) => {
                        if hops.first() != Some(&(u as u32))
                            || hops.last() != Some(&(v as u32))
                        {
                            return Err(format!("({u},{v}): endpoints {hops:?}"));
                        }
                        if hops.len() > n {
                            return Err(format!("({u},{v}): {} hops > n", hops.len()));
                        }
                        let mut sum = 0.0f32;
                        for pair in hops.windows(2) {
                            sum += g
                                .edge_weight(pair[0] as usize, pair[1] as usize)
                                .ok_or_else(|| {
                                    format!("({u},{v}): non-edge {} -> {}", pair[0], pair[1])
                                })?;
                        }
                        // dyadic weights: an exact bit match, not a band
                        if sum.to_bits() != d.to_bits() {
                            return Err(format!(
                                "({u},{v}): path sums to {sum} but dist = {d} \
                                 (bits {:#x} vs {:#x})",
                                sum.to_bits(),
                                d.to_bits()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Dispatch parity: scalar oracle vs SIMD-threaded solve
// -----------------------------------------------------------------

#[test]
fn next_hop_solve_bit_identical_scalar_vs_dispatched() {
    assert_prop(
        12,
        |r| dyadic_graph(r),
        |g| {
            let n = g.n();
            let (dist_fast, next_fast) = query::solve_next_hops(g);
            let (dist_ref, next_ref) = query::solve_next_hops_oracle(g);
            for (i, (a, b)) in dist_fast
                .as_slice()
                .iter()
                .zip(dist_ref.as_slice())
                .enumerate()
            {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "dist[{}][{}]: dispatched {a} != scalar {b}",
                        i / n,
                        i % n
                    ));
                }
            }
            for u in 0..n {
                for v in 0..n {
                    if next_fast.next_hop(u, v) != next_ref.next_hop(u, v) {
                        return Err(format!(
                            "succ[{u}][{v}]: dispatched {:?} != scalar {:?}",
                            next_fast.next_hop(u, v),
                            next_ref.next_hop(u, v)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// k-nearest vs the Dijkstra oracle
// -----------------------------------------------------------------

#[test]
fn knearest_agrees_with_dijkstra_oracle() {
    assert_prop(
        10,
        |r| (dyadic_graph(r), r.next_u64()),
        |(g, seed)| {
            let mut r = Rng::new(*seed);
            let n = g.n();
            let (dist, next) = query::solve_next_hops(g);
            let snap = QuerySnapshot::new(0, dist, next);
            let mut exec = BatchExec::new(8);
            let reqs: Vec<QueryReq> = (0..16)
                .map(|_| QueryReq {
                    tenant: 0,
                    query: Query::KNearest {
                        u: r.gen_range(n) as u32,
                        k: 1 + r.gen_range(10) as u32,
                    },
                })
                .collect();
            let answers = exec.run(&snap, &reqs);
            for (req, ans) in reqs.iter().zip(&answers) {
                let (u, k) = match req.query {
                    Query::KNearest { u, k } => (u as usize, k as usize),
                    _ => unreachable!(),
                };
                let nn = match ans {
                    Answer::KNearest(nn) => nn,
                    other => return Err(format!("knear answered {other:?}")),
                };
                // oracle: sort Dijkstra's SSSP row the same way
                let sssp = dijkstra::sssp(g, u);
                let mut oracle: Vec<(f32, u32)> = sssp
                    .iter()
                    .enumerate()
                    .filter(|&(j, d)| j != u && d.is_finite())
                    .map(|(j, &d)| (d, j as u32))
                    .collect();
                oracle.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                oracle.truncate(k);
                if nn.len() != oracle.len() {
                    return Err(format!(
                        "knear({u},{k}): {} answers, oracle has {}",
                        nn.len(),
                        oracle.len()
                    ));
                }
                for (i, (got, want)) in nn.iter().zip(&oracle).enumerate() {
                    // dyadic weights: FW and Dijkstra agree bit-exactly
                    if got.1 != want.1 || got.0.to_bits() != want.0.to_bits() {
                        return Err(format!(
                            "knear({u},{k})[{i}]: got {got:?}, oracle {want:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Snapshot consistency under a replayed delta script
// -----------------------------------------------------------------

/// `k` distinct existing edges reweighted (both directions of change),
/// mirroring the delta engine's non-structural batches.
fn random_reweights(g: &CsrGraph, r: &mut Rng, k: usize) -> Vec<EdgeDelta> {
    let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).collect();
    let k = k.min(edges.len());
    let mut idx: Vec<usize> = (0..edges.len()).collect();
    for i in 0..k {
        let j = i + r.gen_range(idx.len() - i);
        idx.swap(i, j);
    }
    idx[..k]
        .iter()
        .map(|&e| {
            let (u, v, w) = edges[e];
            let scale = if r.gen_range(2) == 0 { 0.5 } else { 2.0 };
            EdgeDelta::Reweight { u, v, w: w * scale }
        })
        .collect()
}

#[test]
fn snapshot_reads_never_torn_during_delta_replay() {
    assert_prop(
        6,
        |r| (dyadic_graph(r), r.next_u64()),
        |(g, seed)| {
            let mut r = Rng::new(*seed);
            let (dist, next) = query::solve_next_hops(g);
            let cell = SnapshotCell::new(Arc::new(QuerySnapshot::new(0, dist, next)));
            let stop = AtomicBool::new(false);
            let torn = AtomicU64::new(0);
            let loads = AtomicU64::new(0);
            let n_batches = 2 + r.gen_range(3) as u64;
            std::thread::scope(|s| {
                for _ in 0..3 {
                    s.spawn(|| {
                        let mut last_epoch = 0u64;
                        while !stop.load(Ordering::Relaxed) {
                            let snap = cell.load();
                            if !snap.verify() || snap.epoch < last_epoch {
                                torn.fetch_add(1, Ordering::Relaxed);
                            }
                            last_epoch = snap.epoch;
                            loads.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
                let mut cur = g.clone();
                for epoch in 1..=n_batches {
                    let batch = random_reweights(&cur, &mut r, 1 + r.gen_range(6));
                    cur = apply_deltas(&cur, &batch);
                    let (d2, n2) = query::solve_next_hops(&cur);
                    cell.swap(Arc::new(QuerySnapshot::new(epoch, d2, n2)));
                }
                stop.store(true, Ordering::Relaxed);
            });
            if torn.load(Ordering::Relaxed) != 0 {
                return Err(format!(
                    "{} torn/regressed reads observed across {} swaps",
                    torn.load(Ordering::Relaxed),
                    n_batches
                ));
            }
            if loads.load(Ordering::Relaxed) == 0 {
                return Err("readers made no progress during the replay".into());
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Batched answers match direct snapshot reads on mixed workloads
// -----------------------------------------------------------------

#[test]
fn batched_answers_match_direct_reads_on_random_workloads() {
    assert_prop(
        8,
        |r| (dyadic_graph(r), r.next_u64(), 1 + r.gen_range(16)),
        |(g, seed, panel_rows)| {
            let mut r = Rng::new(*seed);
            let n = g.n();
            let (dist, next) = query::solve_next_hops(g);
            let snap = QuerySnapshot::new(0, dist, next);
            let mut exec = BatchExec::new(*panel_rows);
            let reqs: Vec<QueryReq> = (0..100)
                .map(|_| {
                    let u = r.gen_range(n) as u32;
                    let v = r.gen_range(n) as u32;
                    let query = match r.gen_range(4) {
                        0 => Query::Dist { u, v },
                        1 => Query::Path { u, v },
                        2 => Query::KNearest {
                            u,
                            k: 1 + r.gen_range(6) as u32,
                        },
                        _ => Query::Reach { u },
                    };
                    QueryReq { tenant: 0, query }
                })
                .collect();
            let answers = exec.run(&snap, &reqs);
            for (i, (req, ans)) in reqs.iter().zip(&answers).enumerate() {
                let ok = match (req.query, ans) {
                    (Query::Dist { u, v }, Answer::Dist(d)) => {
                        d.to_bits() == snap.dist.get(u as usize, v as usize).to_bits()
                    }
                    (Query::Path { u, v }, Answer::Path { hops, .. }) => {
                        match snap.next.as_ref().unwrap().path(u as usize, v as usize) {
                            Some(p) => hops == &p,
                            None => hops.is_empty(),
                        }
                    }
                    (Query::Reach { u }, Answer::Reach(c)) => {
                        let want = (0..n)
                            .filter(|&j| {
                                j != u as usize && snap.dist.get(u as usize, j).is_finite()
                            })
                            .count();
                        *c as usize == want
                    }
                    (Query::KNearest { .. }, Answer::KNearest(_)) => true, // oracle above
                    _ => false,
                };
                if !ok {
                    return Err(format!(
                        "request {i} ({:?}) answered {ans:?} inconsistently",
                        req.query
                    ));
                }
            }
            Ok(())
        },
    );
}
