//! Property tests for the incremental delta engine (`apsp::delta` +
//! `scheduler::execute_delta`): random delta scripts replayed through
//! the repair path and checked bit-identical against fresh full solves
//! (and against the Dijkstra oracle at 1e-4, the blocked-FW
//! tolerance), dirty-closure monotonicity under batch growth, and
//! store fingerprint sensitivity to every delta kind.
//!
//! All properties run on the seeded harness (`util::prop`); set
//! `RAPID_PROP_SEED` to explore fresh inputs, failures report a replay
//! seed.

use rapid_graph::apsp::backend::NativeBackend;
use rapid_graph::apsp::delta::{
    apply_deltas, classify_deltas, dirty_spec, repair_plan, validate_deltas, DeltaClass, EdgeDelta,
};
use rapid_graph::apsp::plan::{build_plan, ApspPlan, PlanOptions};
use rapid_graph::apsp::recursive::SolveOptions;
use rapid_graph::apsp::scheduler;
use rapid_graph::apsp::store::fingerprint;
use rapid_graph::apsp::validate::validate_sampled;
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;

fn random_graph(r: &mut Rng) -> (CsrGraph, ApspPlan) {
    let n = 150 + r.gen_range(250);
    let topo = match r.gen_range(3) {
        0 => Topology::Nws,
        1 => Topology::Er,
        _ => Topology::Grid,
    };
    let degree = 4.0 + r.gen_f64() * 6.0;
    let seed = r.next_u64();
    let g = generators::generate(topo, n, degree, Weights::Uniform(0.5, 8.0), seed);
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 48,
            max_depth: usize::MAX,
            seed,
        },
    );
    (g, plan)
}

/// A random non-structural batch: reweights (up and down) and deletes
/// of `k` distinct existing edges. Never inserts, so the tile plan is
/// always repairable and every batch takes the repair path.
fn random_repair_batch(g: &CsrGraph, r: &mut Rng, k: usize) -> Vec<EdgeDelta> {
    let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).collect();
    let k = k.min(edges.len());
    let mut idx: Vec<usize> = (0..edges.len()).collect();
    for i in 0..k {
        let j = i + r.gen_range(idx.len() - i);
        idx.swap(i, j);
    }
    idx[..k]
        .iter()
        .map(|&e| {
            let (u, v, w) = edges[e];
            match r.gen_range(4) {
                0 => EdgeDelta::Delete { u, v },
                1 => EdgeDelta::Reweight { u, v, w: w * 2.0 },
                _ => EdgeDelta::Reweight { u, v, w: w * 0.5 },
            }
        })
        .collect()
}

/// An edge absent from `g` (graphs here are far from complete).
fn missing_edge(g: &CsrGraph, r: &mut Rng) -> (u32, u32) {
    loop {
        let u = r.gen_range(g.n()) as u32;
        let v = r.gen_range(g.n()) as u32;
        if u != v && g.edge_weight(u as usize, v as usize).is_none() {
            return (u, v);
        }
    }
}

// -----------------------------------------------------------------
// Replay: repair path bit-identical to fresh full solves
// -----------------------------------------------------------------

#[test]
fn random_scripts_repair_bit_identical_to_fresh_solves() {
    let be = NativeBackend;
    assert_prop(
        10,
        |r| {
            let (g, plan) = random_graph(r);
            let n_batches = 1 + r.gen_range(3);
            let seed = r.next_u64();
            (g, plan, n_batches, seed)
        },
        |(g, plan, n_batches, seed)| {
            let mut r = Rng::new(*seed);
            let opts = SolveOptions::default();
            let mut cur_g = g.clone();
            let mut plan = plan.clone();
            let (_, mut state) = scheduler::solve_dag_retained(&cur_g, &plan, &be, opts);
            for bi in 0..*n_batches {
                let batch = random_repair_batch(&cur_g, &mut r, 1 + r.gen_range(5));
                validate_deltas(&cur_g, &batch)
                    .map_err(|e| format!("batch {bi} failed validation: {e}"))?;
                let class = classify_deltas(&cur_g, &batch);
                let g2 = apply_deltas(&cur_g, &batch);
                let plan2 = repair_plan(&plan, &g2)
                    .ok_or_else(|| format!("batch {bi}: non-structural batch lost the plan"))?;
                let spec = dirty_spec(&plan2, &batch);
                let (repaired, actual) = scheduler::execute_delta(
                    &g2,
                    &plan2,
                    &spec,
                    &state,
                    class == DeltaClass::Improve,
                    &be,
                    opts,
                );
                // the post-execution closure never exceeds the planned one
                if actual.dirty_tiles() > spec.dirty_tiles() {
                    return Err(format!(
                        "batch {bi}: executed closure {} > planned {}",
                        actual.dirty_tiles(),
                        spec.dirty_tiles()
                    ));
                }
                // bit-identity against a fresh retained solve of g2
                let (trace, fresh) = scheduler::solve_dag_retained(&g2, &plan2, &be, opts);
                let diff = repaired.max_diff(&fresh);
                if diff != 0.0 {
                    return Err(format!(
                        "batch {bi} ({}, {} deltas): repair diverged from fresh solve by {diff:e}",
                        class.name(),
                        batch.len()
                    ));
                }
                // and semantic correctness against the Dijkstra oracle
                // (1e-4: the blocked-FW accumulation tolerance)
                let sol = repaired.as_solution(&plan2, &g2, trace);
                let v = validate_sampled(&g2, &sol, 4, 48, 1e-4, *seed ^ bi as u64);
                if !v.ok(1e-4) {
                    return Err(format!(
                        "batch {bi}: repaired solution fails Dijkstra check: \
                         max err {:.2e}, {} mismatches",
                        v.max_abs_err, v.mismatches
                    ));
                }
                cur_g = g2;
                plan = plan2;
                state = repaired;
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Dirty closure: monotone under batch growth
// -----------------------------------------------------------------

#[test]
fn dirty_closure_is_monotone_in_the_batch() {
    assert_prop(
        25,
        |r| {
            let (g, plan) = random_graph(r);
            let mut batch = random_repair_batch(&g, r, 12);
            // inserts participate in the closure even though they may
            // force a replan — dirty_spec is plan-geometry only
            let (u, v) = missing_edge(&g, r);
            batch.push(EdgeDelta::Insert { u, v, w: 1.0 });
            (plan, batch)
        },
        |(plan, batch)| {
            if plan.depth() == 0 {
                return Ok(()); // single-tile plans have a trivial closure
            }
            let mut prev = dirty_spec(plan, &batch[..1]);
            for i in 2..=batch.len() {
                let cur = dirty_spec(plan, &batch[..i]);
                // a superset batch never dirties fewer tiles...
                if cur.dirty_tiles() < prev.dirty_tiles() {
                    return Err(format!(
                        "prefix {i}: {} dirty tiles < prefix {}'s {}",
                        cur.dirty_tiles(),
                        i - 1,
                        prev.dirty_tiles()
                    ));
                }
                // ...and never cleans a flag the smaller batch set
                if prev.boundary_dirty && !cur.boundary_dirty {
                    return Err(format!("prefix {i} cleared boundary_dirty"));
                }
                for (ci, (p, c)) in prev.dirty.iter().zip(&cur.dirty).enumerate() {
                    if *p && !c {
                        return Err(format!("prefix {i} cleared dirty[{ci}]"));
                    }
                }
                for (ci, (p, c)) in prev.rerun.iter().zip(&cur.rerun).enumerate() {
                    if *p && !c {
                        return Err(format!("prefix {i} cleared rerun[{ci}]"));
                    }
                }
                prev = cur;
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Store fingerprint: sensitive to every delta kind
// -----------------------------------------------------------------

#[test]
fn fingerprint_changes_under_every_delta_kind() {
    assert_prop(
        25,
        |r| {
            let (g, _) = random_graph(r);
            let seed = r.next_u64();
            (g, seed)
        },
        |(g, seed)| {
            let mut r = Rng::new(*seed);
            let base = fingerprint(g);
            let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).collect();
            let (u, v, w) = edges[r.gen_range(edges.len())];
            let (mu, mv) = missing_edge(g, &mut r);

            let ins = apply_deltas(g, &[EdgeDelta::Insert { u: mu, v: mv, w: 2.5 }]);
            if fingerprint(&ins) == base {
                return Err(format!("insert {mu}-{mv} left the fingerprint unchanged"));
            }
            let del = apply_deltas(g, &[EdgeDelta::Delete { u, v }]);
            if fingerprint(&del) == base {
                return Err(format!("delete {u}-{v} left the fingerprint unchanged"));
            }
            let rew = apply_deltas(g, &[EdgeDelta::Reweight { u, v, w: w + 1.0 }]);
            if fingerprint(&rew) == base {
                return Err(format!("reweight {u}-{v} left the fingerprint unchanged"));
            }
            // a no-op reweight is the identity: same canonical CSR,
            // same fingerprint (delta invalidation must not churn the
            // store on no-ops)
            let same = apply_deltas(g, &[EdgeDelta::Reweight { u, v, w }]);
            if fingerprint(&same) != base {
                return Err("identity reweight changed the fingerprint".into());
            }
            Ok(())
        },
    );
}
