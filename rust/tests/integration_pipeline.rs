//! Integration tests: the whole pipeline across modules (plan ->
//! recursive solve -> simulate -> validate), both compute backends, and
//! the independent Algorithm-1 implementation as a cross-oracle.

use rapid_graph::apsp::backend::{NativeBackend, SerialBackend};
use rapid_graph::apsp::batch::BatchGraph;
use rapid_graph::apsp::partitioned::partitioned_apsp;
use rapid_graph::apsp::plan::{build_plan, ApspPlan, PlanOptions};
use rapid_graph::apsp::recursive::{solve, LevelSolution, SolveOptions};
use rapid_graph::apsp::shard::ShardGraph;
use rapid_graph::apsp::validate::{validate_full, validate_sampled};
use rapid_graph::apsp::{dijkstra, scheduler, taskgraph, trace::Phase};
use rapid_graph::coordinator::config::{Mode, SystemConfig};
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::sim::engine::{
    simulate, simulate_batch, simulate_dag, simulate_sharded, total_op_seconds,
};
use rapid_graph::sim::params::HwParams;
use rapid_graph::INF;

fn plan_opts(tile: usize, seed: u64) -> PlanOptions {
    PlanOptions {
        tile_limit: tile,
        max_depth: usize::MAX,
        seed,
    }
}

#[test]
fn exactness_across_topologies_and_tiles() {
    for (topo, n, tile) in [
        (Topology::Nws, 500usize, 64usize),
        (Topology::Er, 300, 48),
        (Topology::OgbnProxy, 600, 96),
        (Topology::Grid, 400, 32),
    ] {
        let g = generators::generate(topo, n, 10.0, Weights::Uniform(0.5, 5.0), 11);
        let plan = build_plan(&g, plan_opts(tile, 11));
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let full = sol.materialize_full(&be);
        let v = validate_full(&g, &full, 1e-3);
        assert!(v.ok(1e-3), "{}: {v:?}", topo.name());
    }
}

#[test]
fn three_implementations_agree() {
    // recursive (Alg 2), single-level (Alg 1, independent code), Dijkstra
    let g = generators::generate(Topology::Nws, 350, 8.0, Weights::Uniform(1.0, 6.0), 13);
    let alg1 = partitioned_apsp(&g, 48, 13);
    let plan = build_plan(&g, plan_opts(48, 13));
    let be = SerialBackend;
    let alg2 = solve(&g, &plan, Some(&be), SolveOptions::default()).materialize_full(&be);
    let oracle = dijkstra::apsp(&g);
    assert!(alg1.max_diff(&oracle) < 1e-3);
    assert!(alg2.max_diff(&oracle) < 1e-3);
    assert!(alg1.max_diff(&alg2) < 1e-3);
}

#[test]
fn executor_end_to_end_functional() {
    let g = generators::generate(Topology::OgbnProxy, 2_000, 14.0, Weights::Uniform(1.0, 4.0), 17);
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 256;
    let ex = Executor::new(cfg).unwrap();
    let r = ex.run(&g).unwrap();
    assert!(r.validation.unwrap().ok(1e-3));
    assert!(r.sim.seconds > 0.0 && r.sim.joules > 0.0);
    assert!(r.depth >= 1);
    assert!(r.components_l0 > 1);
}

#[test]
fn estimate_and_functional_traces_identical_at_scale() {
    let g = generators::generate(Topology::Nws, 3_000, 20.0, Weights::Uniform(1.0, 3.0), 19);
    let plan = build_plan(&g, plan_opts(512, 19));
    let be = NativeBackend;
    let func = solve(&g, &plan, Some(&be), SolveOptions::default());
    let est = solve(&g, &plan, None, SolveOptions::default());
    assert_eq!(func.trace, est.trace);
    // and therefore identical simulated cost
    let p = HwParams::default();
    let a = simulate(&func.trace, &p);
    let b = simulate(&est.trace, &p);
    assert_eq!(a.seconds, b.seconds);
    assert_eq!(a.joules, b.joules);
}

#[test]
fn trace_covers_full_dataflow() {
    let g = generators::generate(Topology::OgbnProxy, 4_000, 16.0, Weights::Unit, 23);
    let plan = build_plan(&g, plan_opts(256, 23));
    let est = solve(&g, &plan, None, SolveOptions::default());
    let counts = est.trace.phase_op_counts();
    for phase in [
        Phase::Load,
        Phase::LocalFw,
        Phase::BoundaryBuild,
        Phase::Inject,
        Phase::RerunFw,
        Phase::CrossMerge,
        Phase::Sync,
        Phase::Store,
    ] {
        assert!(counts.contains_key(&phase), "missing {phase:?}");
    }
}

#[test]
fn dag_and_barrier_schedulers_bit_identical_on_pipeline_graphs() {
    // the acceptance gate for the DAG host executor: same graphs as
    // `exactness_across_topologies_and_tiles`, max_diff must be 0.0
    for (topo, n, tile) in [
        (Topology::Nws, 500usize, 64usize),
        (Topology::Er, 300, 48),
        (Topology::OgbnProxy, 600, 96),
        (Topology::Grid, 400, 32),
    ] {
        let g = generators::generate(topo, n, 10.0, Weights::Uniform(0.5, 5.0), 11);
        let plan = build_plan(&g, plan_opts(tile, 11));
        let be = NativeBackend;
        let barrier = solve(&g, &plan, Some(&be), SolveOptions::default());
        let dag = scheduler::solve_dag(&g, &plan, &be, SolveOptions::default());
        assert_eq!(barrier.trace, dag.trace, "{}: traces differ", topo.name());
        let diff = barrier
            .materialize_full(&be)
            .max_diff(&dag.materialize_full(&be));
        assert_eq!(diff, 0.0, "{}: schedulers disagree by {diff}", topo.name());
        // spot queries bit-identical too
        let mut rng = rapid_graph::util::rng::Rng::new(n as u64);
        for _ in 0..200 {
            let (u, v) = (rng.gen_range(g.n()), rng.gen_range(g.n()));
            let (a, b) = (barrier.query(u, v), dag.query(u, v));
            assert!(
                a == b || (a.is_infinite() && b.is_infinite()),
                "{}: query({u},{v}) {a} != {b}",
                topo.name()
            );
        }
    }
}

#[test]
fn cross_component_query_matches_dijkstra_on_all_pairs() {
    // ApspSolution::query's cross-component stitching through dB,
    // exhaustively: multi-component partitioned graph with bridged
    // communities plus a disconnected island (INF pairs included)
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    let mut rng = rapid_graph::util::rng::Rng::new(47);
    let commns = 5u32;
    let csize = 30u32;
    for c in 0..commns {
        let base = c * csize;
        for i in 0..csize {
            for j in (i + 1)..csize {
                if rng.gen_bool(0.3) {
                    edges.push((base + i, base + j, rng.gen_f32_range(1.0, 5.0)));
                }
            }
            // ring inside the community keeps it connected
            edges.push((base + i, base + (i + 1) % csize, rng.gen_f32_range(1.0, 3.0)));
        }
        if c > 0 {
            // two bridges to the previous community
            for _ in 0..2 {
                let u = (c - 1) * csize + rng.gen_range(csize as usize) as u32;
                let v = base + rng.gen_range(csize as usize) as u32;
                edges.push((u, v, rng.gen_f32_range(2.0, 6.0)));
            }
        }
    }
    // disconnected island
    let ibase = commns * csize;
    for i in 0..20u32 {
        for j in (i + 1)..20 {
            edges.push((ibase + i, ibase + j, rng.gen_f32_range(1.0, 2.0)));
        }
    }
    let n = (ibase + 20) as usize;
    let g = CsrGraph::from_undirected_edges(n, &edges);
    let plan = build_plan(&g, plan_opts(32, 47));
    assert!(plan.depth() >= 1, "graph must actually partition");
    let be = NativeBackend;
    for sol in [
        solve(&g, &plan, Some(&be), SolveOptions::default()),
        scheduler::solve_dag(&g, &plan, &be, SolveOptions::default()),
    ] {
        match sol.top().unwrap() {
            LevelSolution::Partitioned { comp_dist, .. } => {
                assert!(comp_dist.len() >= 2, "want a multi-component solution")
            }
            LevelSolution::Direct(_) => panic!("expected a partitioned solution"),
        }
        let oracle = dijkstra::apsp(&g);
        let mut cross_checked = 0u32;
        let mut inf_checked = 0u32;
        for u in 0..n {
            for v in 0..n {
                let q = sol.query(u, v);
                let o = oracle.get(u, v);
                if o.is_finite() {
                    assert!(
                        (q - o).abs() < 1e-3,
                        "query({u},{v}) = {q}, dijkstra {o}"
                    );
                } else {
                    assert_eq!(q, INF, "query({u},{v}) must be INF");
                    inf_checked += 1;
                }
                if u < ibase as usize && v < ibase as usize && u / 30 != v / 30 {
                    cross_checked += 1;
                }
            }
        }
        assert!(cross_checked > 10_000, "cross-component pairs exercised");
        assert!(inf_checked > 1_000, "disconnected pairs exercised");
    }
}

#[test]
fn dag_sim_makespan_never_exceeds_barrier_on_figure_workloads() {
    // fig-workload shapes (scaled to test budget): the dependency-aware
    // schedule may only improve the modeled makespan
    use rapid_graph::bench::workload::Workload;
    let cfgs = [
        Workload::nws(8_000, 70),
        Workload::ogbn_proxy_at(12_000, 88),
        Workload {
            topo: Topology::Er,
            n: 6_000,
            degree: 25.25,
            seed: 99,
        },
    ];
    for w in cfgs {
        let g = w.generate();
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 1024,
                max_depth: usize::MAX,
                seed: w.seed,
            },
        );
        let tg = taskgraph::lower(&plan);
        for prefetch in [true, false] {
            let p = HwParams {
                prefetch,
                ..HwParams::default()
            };
            let barrier = simulate(&tg.to_trace(), &p);
            let dag = simulate_dag(&tg, &p);
            assert!(
                dag.seconds <= barrier.seconds * (1.0 + 1e-9),
                "{} prefetch={prefetch}: dag {} > barrier {}",
                w.label(),
                dag.seconds,
                barrier.seconds
            );
            let ediff = (dag.dynamic_joules - barrier.dynamic_joules).abs();
            assert!(ediff <= 1e-9 * barrier.dynamic_joules.max(1.0));
        }
    }
}

/// Heterogeneous batch workload for the batching invariants: mixed
/// topologies plus the two edge cases the merge must not trip on — a
/// fully disconnected graph (zero boundary at level 0) and a
/// single-tile graph (depth-0 direct solve).
fn batch_workload() -> Vec<CsrGraph> {
    let mut graphs = vec![
        generators::generate(Topology::Nws, 500, 10.0, Weights::Uniform(0.5, 5.0), 61),
        generators::generate(Topology::Er, 300, 10.0, Weights::Uniform(0.5, 5.0), 62),
        generators::generate(Topology::Grid, 400, 4.0, Weights::Uniform(0.5, 5.0), 63),
        generators::generate(Topology::OgbnProxy, 600, 10.0, Weights::Uniform(0.5, 5.0), 64),
    ];
    // disconnected: two cliques, no bridge (overfills one 64-tile, so
    // level 0 partitions with zero boundary)
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    for u in 0..50u32 {
        for v in (u + 1)..50 {
            edges.push((u, v, 1.0));
        }
    }
    for u in 50..100u32 {
        for v in (u + 1)..100 {
            edges.push((u, v, 1.5));
        }
    }
    graphs.push(CsrGraph::from_undirected_edges(100, &edges));
    // single tile: complete graph under the tile limit (direct solve)
    graphs.push(generators::complete(20, Weights::Uniform(1.0, 2.0), 65));
    graphs
}

#[test]
fn batch_solutions_bit_identical_to_solo_runs() {
    let graphs = batch_workload();
    let plans: Vec<ApspPlan> = graphs.iter().map(|g| build_plan(g, plan_opts(64, 7))).collect();
    let batch = BatchGraph::build(&plans.iter().collect::<Vec<_>>());
    let pairs: Vec<(&CsrGraph, &ApspPlan)> = graphs.iter().zip(&plans).collect();
    let be = NativeBackend;
    let sols = scheduler::execute_batch(&pairs, &batch, &be, SolveOptions::default());
    assert_eq!(sols.len(), graphs.len());
    for (i, sol) in sols.iter().enumerate() {
        let solo = scheduler::solve_dag(&graphs[i], &plans[i], &be, SolveOptions::default());
        assert_eq!(solo.trace, sol.trace, "graph {i}: traces differ");
        let diff = solo
            .materialize_full(&be)
            .max_diff(&sol.materialize_full(&be));
        assert_eq!(diff, 0.0, "graph {i}: batch and solo disagree by {diff}");
        // and correct, not just consistent
        let oracle = dijkstra::apsp(&graphs[i]);
        assert!(sol.materialize_full(&be).max_diff(&oracle) < 1e-3, "graph {i}");
    }
}

#[test]
fn batch_sim_bounds_and_energy_attribution() {
    let graphs = batch_workload();
    let plans: Vec<ApspPlan> = graphs.iter().map(|g| build_plan(g, plan_opts(64, 7))).collect();
    let batch = BatchGraph::build(&plans.iter().collect::<Vec<_>>());
    for prefetch in [true, false] {
        let p = HwParams {
            prefetch,
            ..HwParams::default()
        };
        let solos: Vec<_> = batch
            .per_graph
            .iter()
            .map(|tg| simulate_dag(tg, &p))
            .collect();
        let (rep, stats) = simulate_batch(&batch, &p);
        // (b) batch makespan <= Σ solo makespans, >= the longest solo
        let serial: f64 = solos.iter().map(|s| s.seconds).sum();
        let longest = solos.iter().map(|s| s.seconds).fold(0.0, f64::max);
        assert!(
            rep.seconds <= serial * (1.0 + 1e-9),
            "prefetch={prefetch}: batch {} > serial {serial}",
            rep.seconds
        );
        assert!(
            rep.seconds >= longest * (1.0 - 1e-9),
            "prefetch={prefetch}: batch {} < longest solo {longest}",
            rep.seconds
        );
        // (c) per-graph dynamic energy is schedule-independent and
        // partitions the batch total
        for (i, (st, solo)) in stats.iter().zip(&solos).enumerate() {
            assert_eq!(
                st.dynamic_joules, solo.dynamic_joules,
                "graph {i} prefetch={prefetch}: attribution != solo energy"
            );
            assert_eq!(st.madds, solo.madds, "graph {i}");
            assert!(st.makespan <= rep.seconds + 1e-12, "graph {i}");
            let work = total_op_seconds(&batch.per_graph[i], &p);
            assert!(
                (st.busy - work).abs() <= 1e-9 * work.max(1.0),
                "graph {i}: busy {} != op work {work}",
                st.busy
            );
        }
        let esum: f64 = stats.iter().map(|s| s.dynamic_joules).sum();
        assert_eq!(esum, rep.dynamic_joules, "prefetch={prefetch}");
        assert_eq!(stats.iter().map(|s| s.madds).sum::<u64>(), rep.madds);
    }
}

#[test]
fn executor_batch_end_to_end_with_edge_cases() {
    let graphs = batch_workload();
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let b = ex.run_batch(&graphs).unwrap();
    assert_eq!(b.batch_size(), graphs.len());
    for (i, r) in b.per_graph.iter().enumerate() {
        let v = r.validation.as_ref().expect("validation on");
        assert!(v.ok(r.validate_tolerance), "graph {i}: {v:?}");
    }
    assert!(b.batch_sim.seconds <= b.solo_makespan_sum() * (1.0 + 1e-9));
    assert!(b.batch_speedup() >= 1.0 - 1e-9);
    // on a >= 4-graph mixed workload the interleaving must strictly
    // beat serial submission (the acceptance gate's utilization gain)
    assert!(
        b.batch_sim.seconds < b.solo_makespan_sum(),
        "no utilization gain: batch {} vs serial {}",
        b.batch_sim.seconds,
        b.solo_makespan_sum()
    );
}

#[test]
fn admission_solutions_bit_identical_to_solo_runs() {
    use rapid_graph::apsp::admission::{AdmissionConfig, AdmissionGraph};
    // the batch edge-case workload (mixed topologies, a disconnected
    // graph, a single-tile direct solve) submitted through the
    // admission pipeline. queue_depth = 1 splices every graph into an
    // almost-drained (fully parked) pool; deeper queues interleave.
    let graphs = batch_workload();
    let plans: Vec<ApspPlan> = graphs.iter().map(|g| build_plan(g, plan_opts(64, 7))).collect();
    let subs: Vec<(&CsrGraph, &ApspPlan)> = graphs.iter().zip(&plans).collect();
    let arrivals: Vec<f64> = (0..subs.len()).map(|i| i as f64 * 1e-4).collect();
    let be = NativeBackend;
    for queue_depth in [1usize, 3] {
        let cfg = AdmissionConfig {
            queue_depth,
            ..AdmissionConfig::default()
        };
        let adm = AdmissionGraph::build(&subs, &arrivals, &cfg);
        assert_eq!(adm.n_admitted(), graphs.len());
        let completions = std::sync::Mutex::new(Vec::new());
        let sols = scheduler::execute_admission(&subs, &adm, &be, |si| {
            completions.lock().unwrap().push(si);
        });
        // every graph completed exactly once, each callback fired
        let mut done = completions.into_inner().unwrap();
        done.sort_unstable();
        assert_eq!(done, (0..graphs.len()).collect::<Vec<_>>());
        for (i, sol) in sols.iter().enumerate() {
            let sol = sol.as_ref().expect("admitted graph must have a solution");
            let solo = scheduler::solve_dag(&graphs[i], &plans[i], &be, SolveOptions::default());
            assert_eq!(solo.trace, sol.trace, "graph {i}: traces differ");
            let diff = solo
                .materialize_full(&be)
                .max_diff(&sol.materialize_full(&be));
            assert_eq!(
                diff, 0.0,
                "graph {i} queue {queue_depth}: admission differs from solo"
            );
            // and correct, not just consistent
            let oracle = dijkstra::apsp(&graphs[i]);
            assert!(sol.materialize_full(&be).max_diff(&oracle) < 1e-3, "graph {i}");
        }
    }
}

#[test]
fn admission_beats_drain_rebatch_on_staggered_six_graph_workload() {
    use rapid_graph::apsp::batch::BatchGraph;
    use rapid_graph::sim::engine::{simulate_admission, simulate_drain_rebatch};
    // the acceptance gate: six heterogeneous graphs arriving staggered
    // must finish sooner when spliced into the live schedule than when
    // the coordinator drains and rebatches between arrivals
    let specs: [(Topology, usize, f64, u64); 6] = [
        (Topology::Nws, 3_000, 12.0, 91),
        (Topology::Er, 2_000, 10.0, 92),
        (Topology::Grid, 2_500, 4.0, 93),
        (Topology::OgbnProxy, 4_000, 14.0, 94),
        (Topology::Nws, 1_500, 20.0, 95),
        (Topology::OgbnProxy, 2_500, 10.0, 96),
    ];
    let tgs: Vec<_> = specs
        .iter()
        .map(|&(topo, n, degree, seed)| {
            let g = generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), seed);
            taskgraph::lower(&build_plan(&g, plan_opts(1024, seed)))
        })
        .collect();
    let p = HwParams::default();
    let first = simulate_dag(&tgs[0], &p).seconds;
    let arrivals: Vec<f64> = (0..tgs.len()).map(|i| i as f64 * 0.15 * first).collect();
    let batch = BatchGraph::merge(tgs);
    let (rep, stats) = simulate_admission(&batch, &arrivals, batch.n_graphs(), &p);
    let (drain, _) = simulate_drain_rebatch(&batch.per_graph, &arrivals, &p);
    assert!(
        rep.seconds < drain,
        "live admission {} !< drain-and-rebatch {drain}",
        rep.seconds
    );
    // completion timestamps respect the arrival schedule
    for (st, &a) in stats.iter().zip(&arrivals) {
        assert!(st.makespan > a);
        assert!(st.makespan <= rep.seconds + 1e-12);
    }
    // the executor-level view agrees: speedup over the drain baseline
    // (queue deep enough for the whole workload, so the gain measured
    // is splice-vs-drain, not queue backpressure)
    let mut cfg = SystemConfig::default();
    cfg.mode = Mode::Estimate;
    cfg.tile_limit = 1024;
    cfg.admission_arrivals = arrivals;
    cfg.admission_queue_depth = 6;
    let ex = Executor::new(cfg).unwrap();
    let graphs: Vec<CsrGraph> = specs
        .iter()
        .map(|&(topo, n, degree, seed)| {
            generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), seed)
        })
        .collect();
    let a = ex.run_admission(&graphs).unwrap();
    assert_eq!(a.n_admitted(), 6);
    assert!(
        a.admission_speedup() > 1.0,
        "admission speedup {} must beat the drain baseline",
        a.admission_speedup()
    );
    for r in &a.per_graph {
        assert!(r.latency > 0.0);
    }
}

#[test]
fn admission_store_serves_midstream_duplicate_end_to_end() {
    use rapid_graph::apsp::admission::{AdmissionConfig, AdmissionGraph, StoreOutcome};
    use rapid_graph::apsp::store::MemoryStore;
    // a duplicate of the first graph re-submitted mid-stream: the
    // executor must give it a HIT verdict, a modeled latency strictly
    // below the solve it skipped, a solution bit-identical to a fresh
    // solve, and energy attribution that still partitions the shared
    // timeline exactly
    let gen = |n: usize, seed: u64| {
        generators::generate(Topology::Nws, n, 8.0, Weights::Uniform(1.0, 5.0), seed)
    };
    let graphs = vec![gen(400, 81), gen(300, 82), gen(400, 81), gen(350, 83)];
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    cfg.admission_interval = 1e-4;
    cfg.store_enabled = true;
    cfg.store_capacity = 4;
    let ex = Executor::new(cfg).unwrap();
    let a = ex.run_admission(&graphs).unwrap();
    assert_eq!(a.n_admitted(), 4);
    assert_eq!(a.n_store_hits(), 1);
    // verdicts: producer stored, duplicate hit, the rest miss
    assert_eq!(a.per_graph[0].store, Some(StoreOutcome::MissStored));
    assert!(matches!(
        a.per_graph[2].store,
        Some(StoreOutcome::Hit { source: Some(0), .. })
    ));
    assert_eq!(a.per_graph[3].store, Some(StoreOutcome::MissStored));
    // every admitted solution — the hit-served one included — validates
    // against Dijkstra ground truth
    for (i, r) in a.per_graph.iter().enumerate() {
        let solo = r.solo.as_ref().expect("admitted");
        let v = solo.validation.as_ref().expect("functional mode validates");
        assert!(v.ok(solo.validate_tolerance), "graph {i}: {v:?}");
    }
    // the hit's admit-to-complete latency sits strictly below the solo
    // solve it skipped (the FeNAND read is far cheaper than the solve)
    let hit = &a.per_graph[2];
    let hit_solo = hit.solo.as_ref().unwrap();
    assert!(hit.latency > 0.0);
    assert!(
        hit.latency < hit_solo.sim.seconds,
        "hit latency {} !< solo solve {}",
        hit.latency,
        hit_solo.sim.seconds
    );
    // per-graph dynamic energy partitions the admission total exactly,
    // store ops included (same construction as the batch attribution)
    let esum: f64 = a
        .per_graph
        .iter()
        .filter_map(|r| r.stat.as_ref())
        .map(|s| s.dynamic_joules)
        .sum();
    assert_eq!(esum, a.admission_sim.dynamic_joules);
    let msum: u64 = a
        .per_graph
        .iter()
        .filter_map(|r| r.stat.as_ref())
        .map(|s| s.madds)
        .sum();
    assert_eq!(msum, a.admission_sim.madds);
    // the cache summary is populated and the no-store baseline exists
    assert!(a.no_store_makespan.unwrap() > 0.0);
    assert!(a.cache_speedup().unwrap().is_finite());

    // bit-identity of the served solution, at the scheduler layer on
    // the same workload (max_diff must be exactly 0.0, not tolerant)
    let plans: Vec<ApspPlan> = graphs.iter().map(|g| build_plan(g, plan_opts(64, 7))).collect();
    let subs: Vec<(&CsrGraph, &ApspPlan)> = graphs.iter().zip(&plans).collect();
    let arrivals: Vec<f64> = (0..subs.len()).map(|i| i as f64 * 1e-4).collect();
    let mut store = MemoryStore::new(4, 1 << 32);
    let (adm, outcomes) = AdmissionGraph::build_with_store(
        &subs,
        &arrivals,
        &AdmissionConfig::default(),
        &mut store,
        true,
    );
    let be = NativeBackend;
    let sols = scheduler::execute_admission_stored(&subs, &adm, &outcomes, &be, |_| {});
    let served = sols[2].as_ref().expect("hit solution");
    let fresh = scheduler::solve_dag(&graphs[2], &plans[2], &be, SolveOptions::default());
    let diff = served
        .materialize_full(&be)
        .max_diff(&fresh.materialize_full(&be));
    assert_eq!(diff, 0.0, "hit-served solution must be bit-identical");
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_agrees_with_native_when_artifacts_exist() {
    use rapid_graph::apsp::backend::TileBackend;
    let dir = rapid_graph::runtime::Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let runtime = rapid_graph::runtime::PjrtRuntime::load(&dir).unwrap();
    let pjrt = rapid_graph::runtime::PjrtBackend::new(&runtime);
    let g = generators::generate(Topology::Nws, 700, 10.0, Weights::Uniform(1.0, 5.0), 29);
    let plan = build_plan(&g, plan_opts(128, 29));
    let native = NativeBackend;
    let sol_p = solve(&g, &plan, Some(&pjrt as &dyn TileBackend), SolveOptions::default());
    let sol_n = solve(&g, &plan, Some(&native), SolveOptions::default());
    let full_p = sol_p.materialize_full(&pjrt);
    let full_n = sol_n.materialize_full(&native);
    assert!(full_p.max_diff(&full_n) < 1e-3);
    let v = validate_sampled(&g, &sol_p, 12, 30, 1e-3, 31);
    assert!(v.ok(1e-3), "{v:?}");
}

/// Shard-equivalence workload: the pipeline topologies plus the two
/// edge cases sharding must not trip on — a fully disconnected graph
/// (no boundary, no dB) and a single-tile graph smaller than the stack
/// count (every stack but the hub idles).
fn shard_workload() -> Vec<CsrGraph> {
    let mut graphs = vec![
        generators::generate(Topology::Nws, 500, 10.0, Weights::Uniform(0.5, 5.0), 71),
        generators::generate(Topology::Er, 300, 10.0, Weights::Uniform(0.5, 5.0), 72),
        generators::generate(Topology::Grid, 400, 4.0, Weights::Uniform(0.5, 5.0), 73),
        generators::generate(Topology::OgbnProxy, 600, 10.0, Weights::Uniform(0.5, 5.0), 74),
    ];
    // disconnected: two cliques, no bridge (zero boundary at level 0)
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    for u in 0..50u32 {
        for v in (u + 1)..50 {
            edges.push((u, v, 1.0));
        }
    }
    for u in 50..100u32 {
        for v in (u + 1)..100 {
            edges.push((u, v, 1.5));
        }
    }
    graphs.push(CsrGraph::from_undirected_edges(100, &edges));
    // smaller than the stack count: a single-tile direct solve sharded
    // across up to 4 stacks
    graphs.push(generators::complete(20, Weights::Uniform(1.0, 2.0), 75));
    graphs
}

#[test]
fn sharded_execution_bit_identical_to_solo_for_every_stack_count() {
    let be = NativeBackend;
    for (gi, g) in shard_workload().iter().enumerate() {
        let plan = build_plan(g, plan_opts(64, 7));
        let solo = scheduler::solve_dag(g, &plan, &be, SolveOptions::default());
        let oracle = dijkstra::apsp(g);
        for stacks in [1usize, 2, 4] {
            let shard = ShardGraph::build(&plan, stacks, 7);
            let sol = scheduler::execute_sharded(g, &plan, &shard, &be, SolveOptions::default());
            assert_eq!(solo.trace, sol.trace, "graph {gi} S={stacks}: traces differ");
            let diff = solo
                .materialize_full(&be)
                .max_diff(&sol.materialize_full(&be));
            assert_eq!(
                diff, 0.0,
                "graph {gi} S={stacks}: sharded and solo disagree by {diff}"
            );
            // and correct, not just consistent
            assert!(
                sol.materialize_full(&be).max_diff(&oracle) < 1e-3,
                "graph {gi} S={stacks}"
            );
        }
    }
}

#[test]
fn sharded_sim_energy_attribution_partitions_total() {
    for g in shard_workload() {
        let plan = build_plan(&g, plan_opts(64, 7));
        for stacks in [1usize, 2, 4] {
            let shard = ShardGraph::build(&plan, stacks, 7);
            let p = HwParams::default();
            let (rep, stats) = simulate_sharded(&shard, &p);
            assert_eq!(stats.len(), stacks);
            // per-stack dynamic energy partitions the sharded total
            // exactly (same construction as the batch attribution)
            let esum: f64 = stats.iter().map(|s| s.dynamic_joules).sum();
            assert_eq!(esum, rep.dynamic_joules, "S={stacks}");
            assert_eq!(stats.iter().map(|s| s.madds).sum::<u64>(), rep.madds);
            for (s, st) in stats.iter().enumerate() {
                assert!(st.makespan <= rep.seconds + 1e-12, "stack {s}");
            }
            // sharded dynamic work = solo work + interconnect traffic
            let solo = simulate_dag(&shard.solo, &p);
            assert!(
                rep.dynamic_joules >= solo.dynamic_joules - 1e-12,
                "S={stacks}: sharding must not lose work"
            );
            if stacks == 1 {
                assert_eq!(rep.seconds, solo.seconds);
                assert_eq!(rep.interconnect_busy, 0.0);
            } else if shard.xfer_bytes > 0 {
                assert!(rep.interconnect_busy > 0.0, "S={stacks}");
            }
        }
    }
}

#[test]
fn sharded_makespan_at_4_stacks_beats_solo_on_figure_workloads() {
    // the acceptance gate: on the large figure workload shapes (the
    // fig-8/9 OGBN-proxy headline and the fig-9 topology sweep's NWS)
    // the 4-stack sharded schedule must beat the 1-stack solo makespan
    use rapid_graph::bench::workload::Workload;
    let cfgs = [
        Workload::ogbn_proxy_at(30_000, 88),
        Workload::nws(24_000, 70),
    ];
    for w in cfgs {
        let g = w.generate();
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 1024,
                max_depth: usize::MAX,
                seed: w.seed,
            },
        );
        let p = HwParams::default();
        let shard = ShardGraph::build(&plan, 4, w.seed);
        let (rep, _) = simulate_sharded(&shard, &p);
        let solo = simulate_dag(&shard.solo, &p);
        assert!(
            rep.seconds < solo.seconds,
            "{}: sharded {} !< solo {}",
            w.label(),
            rep.seconds,
            solo.seconds
        );
    }
}

#[test]
fn ablation_knobs_change_cost_monotonically() {
    let g = generators::generate(Topology::Nws, 5_000, 20.0, Weights::Unit, 37);
    let mut cfg = SystemConfig::default();
    cfg.mode = Mode::Estimate;
    let base = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();

    cfg.hw.prefetch = false;
    let no_prefetch = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
    assert!(no_prefetch.sim.seconds >= base.sim.seconds);
    cfg.hw.prefetch = true;

    cfg.hw.permutation_unit = false;
    let no_perm = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
    assert!(no_perm.sim.seconds > base.sim.seconds);
    cfg.hw.permutation_unit = true;

    cfg.hw.comparator_tree = false;
    let no_tree = Executor::new(cfg.clone()).unwrap().run(&g).unwrap();
    assert!(no_tree.sim.seconds > base.sim.seconds);
}

#[test]
fn weighted_and_unit_graphs_both_exact() {
    for weights in [Weights::Unit, Weights::Uniform(0.1, 99.0)] {
        let g = generators::generate(Topology::Er, 250, 8.0, weights, 41);
        let plan = build_plan(&g, plan_opts(40, 41));
        let be = NativeBackend;
        let sol = solve(&g, &plan, Some(&be), SolveOptions::default());
        let v = validate_sampled(&g, &sol, 25, 40, 1e-2, 43);
        assert!(v.ok(1e-2), "{weights:?}: {v:?}");
    }
}
