//! Property suite for the host hot-path microkernels and the tile
//! arena (PR "host hot-path overhaul").
//!
//! The contract under test: every restructured kernel — the SIMD-
//! dispatching relax microkernel, the fused 4-row variant, the blocked
//! min-plus, the cache-blocked FW compositions — is **bit-identical**
//! to the always-available scalar oracle, across random sizes, strides,
//! INF patterns, and non-divisible block edges. Seeded via `util::prop`
//! (replay with `RAPID_PROP_SEED`).
//!
//! Inputs deliberately avoid NaN and -0.0: weights are non-negative and
//! unreachable entries are +INF, exactly like the production matrices,
//! which is the precondition for `vminps`/`f32::min` bit-equality.

use rapid_graph::apsp::backend::{
    fw_blocked, NativeBackend, ScalarBackend, SerialBackend, SimdBackend, TileBackend,
};
use rapid_graph::apsp::floyd_warshall::{
    fw_inplace, fw_panel, fw_panel_scratch, fw_parallel, fw_rowwise, fw_rowwise_scratch,
    relax_row, relax_row_scalar, relax_rows4,
};
use rapid_graph::apsp::minplus::{minplus_into, minplus_into_parallel, minplus_into_scalar};
use rapid_graph::apsp::plan::{build_plan, PlanOptions};
use rapid_graph::apsp::scheduler::plan_tile_census;
use rapid_graph::graph::dense::DistMatrix;
use rapid_graph::graph::generators::{self, Weights};
use rapid_graph::util::arena::TileArena;
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;

const INF: f32 = f32::INFINITY;

fn rand_row(rng: &mut Rng, n: usize, inf_frac: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.gen_bool(inf_frac) {
                INF
            } else {
                rng.gen_f32_range(0.0, 10.0)
            }
        })
        .collect()
}

/// Exact (bitwise) equality of two f32 slices — `==` would conflate
/// 0.0 and -0.0 and reject NaN; bit comparison pins the real contract.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn relax_dispatch_bit_identical_to_scalar() {
    // random lengths straddle the 8-lane SIMD boundary (0..=40 covers
    // empty, sub-vector, exact multiples, and ragged tails)
    assert_prop(
        120,
        |r| {
            let n = r.gen_range(41);
            let mut rr = r.fork();
            let row_i = rand_row(&mut rr, n, 0.25);
            let row_k = rand_row(&mut rr, n, 0.25);
            let dik = rr.gen_f32_range(0.0, 8.0); // relax_row wants finite dik
            (row_i, row_k, dik)
        },
        |(row_i, row_k, dik)| {
            let mut fast = row_i.clone();
            relax_row(&mut fast, *dik, row_k);
            let mut oracle = row_i.clone();
            relax_row_scalar(&mut oracle, *dik, row_k);
            if bits_eq(&fast, &oracle) {
                Ok(())
            } else {
                Err(format!(
                    "dispatched relax diverged from scalar (n={})",
                    row_i.len()
                ))
            }
        },
    );
}

#[test]
fn rows4_bit_identical_to_sequential() {
    // fused 4-row kernel vs four sequential relaxes, with INF lanes
    // exercising the "INF candidate never wins a min" neutrality
    assert_prop(
        80,
        |r| {
            let n = r.gen_range(33);
            let mut rr = r.fork();
            let rows: Vec<Vec<f32>> = (0..4).map(|_| rand_row(&mut rr, n, 0.2)).collect();
            let rk = rand_row(&mut rr, n, 0.2);
            let dik: [f32; 4] = std::array::from_fn(|_| {
                if rr.gen_bool(0.25) {
                    INF
                } else {
                    rr.gen_f32_range(0.0, 6.0)
                }
            });
            (rows, rk, dik)
        },
        |(rows, rk, dik)| {
            let mut fused = rows.clone();
            let (a, rest) = fused.split_at_mut(1);
            let (b, rest2) = rest.split_at_mut(1);
            let (c, d) = rest2.split_at_mut(1);
            relax_rows4(&mut a[0], &mut b[0], &mut c[0], &mut d[0], *dik, rk);
            let mut seq = rows.clone();
            for (row, &dk) in seq.iter_mut().zip(dik) {
                if dk < INF {
                    relax_row_scalar(row, dk, rk);
                }
            }
            for (f, s) in fused.iter().zip(&seq) {
                if !bits_eq(f, s) {
                    return Err(format!("fused 4-row relax diverged (n={})", rk.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fw_variants_bit_identical_to_oracle() {
    // every FW entry point (owned and caller-scratch) against the naive
    // triple loop, on random connected graphs of odd sizes
    assert_prop(
        12,
        |r| {
            let n = 2 + r.gen_range(70);
            let m = n + r.gen_range(3 * n);
            let seed = r.gen_range(1 << 30) as u64;
            generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed).to_dense()
        },
        |base| {
            let mut oracle = base.clone();
            fw_inplace(&mut oracle);
            let n = base.n();
            let variants: Vec<(&str, DistMatrix)> = vec![
                ("rowwise", {
                    let mut d = base.clone();
                    fw_rowwise(&mut d);
                    d
                }),
                ("rowwise_scratch", {
                    let mut d = base.clone();
                    let mut row_k = vec![0f32; n];
                    fw_rowwise_scratch(&mut d, &mut row_k);
                    d
                }),
                ("parallel", {
                    let mut d = base.clone();
                    fw_parallel(&mut d);
                    d
                }),
                ("panel", {
                    let mut d = base.clone();
                    fw_panel(&mut d);
                    d
                }),
                ("panel_scratch", {
                    let mut d = base.clone();
                    let (mut pr, mut pc) = (vec![0f32; n], vec![0f32; n]);
                    fw_panel_scratch(&mut d, &mut pr, &mut pc);
                    d
                }),
            ];
            for (name, got) in &variants {
                if oracle.max_diff(got) != 0.0 {
                    return Err(format!("fw_{name} != fw_inplace (n={n})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn minplus_blocked_bit_identical_to_scalar() {
    // blocked 4-row microkernel and the parallel splitter vs the scalar
    // oracle and a naive reference, across ragged (m, k, n) incl. the
    // quad remainder rows and empty inner dims
    assert_prop(
        60,
        |r| {
            let (m, k, n) = (
                1 + r.gen_range(18),
                1 + r.gen_range(18),
                1 + r.gen_range(18),
            );
            let mut rr = r.fork();
            let a = rand_row(&mut rr, m * k, 0.25);
            let b = rand_row(&mut rr, k * n, 0.25);
            let c0 = rand_row(&mut rr, m * n, 0.5);
            (a, b, c0, (m, k, n))
        },
        |(a, b, c0, (m, k, n))| {
            let (m, k, n) = (*m, *k, *n);
            let mut naive = c0.clone();
            for i in 0..m {
                for kk in 0..k {
                    let aik = a[i * k + kk];
                    if aik >= INF {
                        continue;
                    }
                    for j in 0..n {
                        let cand = aik + b[kk * n + j];
                        if cand < naive[i * n + j] {
                            naive[i * n + j] = cand;
                        }
                    }
                }
            }
            let mut scalar = c0.clone();
            minplus_into_scalar(&mut scalar, a, b, m, k, n);
            let mut blocked = c0.clone();
            minplus_into(&mut blocked, a, b, m, k, n);
            let mut par = c0.clone();
            minplus_into_parallel(&mut par, a, b, m, k, n);
            if !bits_eq(&scalar, &naive) {
                return Err(format!("scalar oracle != naive ({m}x{k}x{n})"));
            }
            if !bits_eq(&blocked, &scalar) {
                return Err(format!("blocked minplus != scalar ({m}x{k}x{n})"));
            }
            if !bits_eq(&par, &scalar) {
                return Err(format!("parallel minplus != scalar ({m}x{k}x{n})"));
            }
            Ok(())
        },
    );
}

#[test]
fn fw_blocked_backends_agree_on_ragged_edges() {
    // non-divisible block edges: the scalar-pinned and SIMD-dispatching
    // backends must compose fw_blocked **bit-identically** (same op
    // order, bit-equal primitives); the blocked result itself is only
    // tolerance-close to the direct solve (Katz–Kider reassociates)
    assert_prop(
        8,
        |r| {
            let n = 20 + r.gen_range(110);
            let block = 8 + r.gen_range(40);
            let m = 2 * n + r.gen_range(2 * n);
            let seed = r.gen_range(1 << 30) as u64;
            let d = generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed).to_dense();
            (d, block)
        },
        |(base, block)| {
            let (n, block) = (base.n(), *block);
            let mut via_scalar = base.clone();
            fw_blocked(&ScalarBackend, &mut via_scalar, block);
            let mut via_simd = base.clone();
            fw_blocked(&SimdBackend, &mut via_simd, block);
            if via_scalar.max_diff(&via_simd) != 0.0 {
                return Err(format!(
                    "fw_blocked scalar vs simd diverged (n={n} block={block})"
                ));
            }
            let mut direct = base.clone();
            fw_inplace(&mut direct);
            let diff = direct.max_diff(&via_scalar);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!(
                    "fw_blocked off by {diff} vs direct (n={n} block={block})"
                ))
            }
        },
    );
}

#[test]
fn all_backends_agree_bitwise() {
    let g = generators::random_connected(96, 300, Weights::Uniform(0.5, 4.0), 77);
    let base = g.to_dense();
    let mut oracle = base.clone();
    ScalarBackend.fw(&mut oracle);
    for be in [
        &SerialBackend as &dyn TileBackend,
        &SimdBackend,
        &NativeBackend,
    ] {
        let mut d = base.clone();
        be.fw(&mut d);
        assert_eq!(oracle.max_diff(&d), 0.0, "fw backend {}", be.name());
    }
    let mut rng = Rng::new(78);
    let (m, k, n) = (41usize, 23usize, 37usize);
    let a = rand_row(&mut rng, m * k, 0.3);
    let b = rand_row(&mut rng, k * n, 0.0);
    let mut c_oracle = vec![INF; m * n];
    ScalarBackend.minplus_into(&mut c_oracle, &a, &b, m, k, n);
    for be in [
        &SerialBackend as &dyn TileBackend,
        &SimdBackend,
        &NativeBackend,
    ] {
        let mut c = vec![INF; m * n];
        be.minplus_into(&mut c, &a, &b, m, k, n);
        assert!(bits_eq(&c, &c_oracle), "minplus backend {}", be.name());
    }
}

// ---- tile arena invariants ----

#[test]
fn arena_never_serves_one_buffer_to_two_live_leases() {
    assert_prop(
        20,
        |r| {
            let sizes: Vec<usize> = (0..(2 + r.gen_range(30)))
                .map(|_| 1 + r.gen_range(500))
                .collect();
            sizes
        },
        |sizes| {
            let mut arena = TileArena::new();
            // interleave: lease half, recycle some, lease the rest —
            // every simultaneously-live buffer must be distinct storage
            let mut live: Vec<Vec<f32>> = Vec::new();
            for (i, &len) in sizes.iter().enumerate() {
                live.push(arena.lease_filled(len, 0.0));
                if i % 3 == 2 {
                    let buf = live.remove(0);
                    arena.recycle(buf);
                }
                let mut ptrs: Vec<usize> =
                    live.iter().map(|b| b.as_ptr() as usize).collect();
                ptrs.sort_unstable();
                ptrs.dedup();
                if ptrs.len() != live.len() {
                    return Err("two live leases share a backing store".into());
                }
            }
            let stats = arena.stats();
            if stats.live != live.len() {
                return Err(format!(
                    "live accounting off: {} tracked vs {} held",
                    stats.live,
                    live.len()
                ));
            }
            for buf in live.drain(..) {
                arena.recycle(buf);
            }
            if arena.stats().live != 0 {
                return Err("live count nonzero after recycling everything".into());
            }
            Ok(())
        },
    );
}

#[test]
fn arena_high_water_bounded_by_plan_census() {
    // replay a DAG run's slot lifecycle against a private pool: lease
    // one buffer per census entry (the worst case — every slot live at
    // once), and check (a) the census accounting matches the plan, and
    // (b) a second run is served entirely from the pool (alloc plateau)
    let g = generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 41);
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 48,
            max_depth: usize::MAX,
            seed: 41,
        },
    );
    let census_elems = plan_tile_census(&plan);

    // enumerate the slot sizes exactly as plan_tile_census counts them
    let depth = plan.depth();
    let mut sizes: Vec<usize> = vec![plan.final_n * plan.final_n];
    for (l, lvl) in plan.levels.iter().enumerate() {
        for c in &lvl.cs.components {
            sizes.push(c.n() * c.n());
        }
        sizes.push(if l + 1 < depth {
            plan.levels[l + 1].n * plan.levels[l + 1].n
        } else {
            plan.final_n * plan.final_n
        });
    }
    assert_eq!(
        sizes.iter().sum::<usize>(),
        census_elems,
        "census enumeration drifted from plan_tile_census"
    );

    let mut arena = TileArena::new();
    let run = |arena: &mut TileArena| {
        let live: Vec<Vec<f32>> = sizes.iter().map(|&s| arena.lease_filled(s, 0.0)).collect();
        assert!(
            arena.stats().high_water <= sizes.len(),
            "high water {} exceeds census slot count {}",
            arena.stats().high_water,
            sizes.len()
        );
        for buf in live {
            arena.recycle(buf);
        }
    };
    run(&mut arena);
    let allocs_after_first = arena.stats().allocs;
    run(&mut arena);
    assert_eq!(
        arena.stats().allocs,
        allocs_after_first,
        "second run should be allocation-free (full pool reuse)"
    );
    assert_eq!(arena.stats().live, 0);
}
