//! Property suite for the host hot-path microkernels and the tile
//! arena (PR "host hot-path overhaul").
//!
//! The contract under test: every restructured kernel — the SIMD-
//! dispatching relax microkernel, the fused 4-row variant, the blocked
//! min-plus, the cache-blocked FW compositions — is **bit-identical**
//! to the always-available scalar oracle, across random sizes, strides,
//! INF patterns, and non-divisible block edges. Seeded via `util::prop`
//! (replay with `RAPID_PROP_SEED`).
//!
//! Inputs deliberately avoid NaN and -0.0: weights are non-negative and
//! unreachable entries are +INF, exactly like the production matrices,
//! which is the precondition for `vminps`/`f32::min` bit-equality.
//!
//! The semiring section extends the same contract to the generic DP
//! engine: the runtime-dispatched kernels must be bit-identical to a
//! naive ⊕/⊗ oracle for every shipped instance, reachability must
//! match a BFS oracle, widest-path a modified-Dijkstra oracle, and the
//! `MinPlus` instance must reproduce the pre-refactor scalar kernels
//! (frozen verbatim in this file) bit-for-bit.

use rapid_graph::apsp::backend::{
    fw_blocked, NativeBackend, ScalarBackend, SerialBackend, SimdBackend, TileBackend,
};
use rapid_graph::apsp::floyd_warshall::{
    fw_inplace, fw_panel, fw_panel_scratch, fw_parallel, fw_parallel_dyn, fw_rowwise,
    fw_rowwise_dyn, fw_rowwise_scratch, relax_row, relax_row_scalar, relax_rows4,
};
use rapid_graph::apsp::minplus::{
    minplus_into, minplus_into_parallel, minplus_into_scalar, product_into_dyn,
};
use rapid_graph::apsp::semiring::{SemiringId, ALL_SEMIRINGS};
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::apsp::plan::{build_plan, PlanOptions};
use rapid_graph::apsp::scheduler::plan_tile_census;
use rapid_graph::graph::dense::DistMatrix;
use rapid_graph::graph::generators::{self, Weights};
use rapid_graph::util::arena::TileArena;
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;

const INF: f32 = f32::INFINITY;

fn rand_row(rng: &mut Rng, n: usize, inf_frac: f64) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if rng.gen_bool(inf_frac) {
                INF
            } else {
                rng.gen_f32_range(0.0, 10.0)
            }
        })
        .collect()
}

/// Exact (bitwise) equality of two f32 slices — `==` would conflate
/// 0.0 and -0.0 and reject NaN; bit comparison pins the real contract.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn relax_dispatch_bit_identical_to_scalar() {
    // random lengths straddle the 8-lane SIMD boundary (0..=40 covers
    // empty, sub-vector, exact multiples, and ragged tails)
    assert_prop(
        120,
        |r| {
            let n = r.gen_range(41);
            let mut rr = r.fork();
            let row_i = rand_row(&mut rr, n, 0.25);
            let row_k = rand_row(&mut rr, n, 0.25);
            let dik = rr.gen_f32_range(0.0, 8.0); // relax_row wants finite dik
            (row_i, row_k, dik)
        },
        |(row_i, row_k, dik)| {
            let mut fast = row_i.clone();
            relax_row(&mut fast, *dik, row_k);
            let mut oracle = row_i.clone();
            relax_row_scalar(&mut oracle, *dik, row_k);
            if bits_eq(&fast, &oracle) {
                Ok(())
            } else {
                Err(format!(
                    "dispatched relax diverged from scalar (n={})",
                    row_i.len()
                ))
            }
        },
    );
}

#[test]
fn rows4_bit_identical_to_sequential() {
    // fused 4-row kernel vs four sequential relaxes, with INF lanes
    // exercising the "INF candidate never wins a min" neutrality
    assert_prop(
        80,
        |r| {
            let n = r.gen_range(33);
            let mut rr = r.fork();
            let rows: Vec<Vec<f32>> = (0..4).map(|_| rand_row(&mut rr, n, 0.2)).collect();
            let rk = rand_row(&mut rr, n, 0.2);
            let dik: [f32; 4] = std::array::from_fn(|_| {
                if rr.gen_bool(0.25) {
                    INF
                } else {
                    rr.gen_f32_range(0.0, 6.0)
                }
            });
            (rows, rk, dik)
        },
        |(rows, rk, dik)| {
            let mut fused = rows.clone();
            let (a, rest) = fused.split_at_mut(1);
            let (b, rest2) = rest.split_at_mut(1);
            let (c, d) = rest2.split_at_mut(1);
            relax_rows4(&mut a[0], &mut b[0], &mut c[0], &mut d[0], *dik, rk);
            let mut seq = rows.clone();
            for (row, &dk) in seq.iter_mut().zip(dik) {
                if dk < INF {
                    relax_row_scalar(row, dk, rk);
                }
            }
            for (f, s) in fused.iter().zip(&seq) {
                if !bits_eq(f, s) {
                    return Err(format!("fused 4-row relax diverged (n={})", rk.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fw_variants_bit_identical_to_oracle() {
    // every FW entry point (owned and caller-scratch) against the naive
    // triple loop, on random connected graphs of odd sizes
    assert_prop(
        12,
        |r| {
            let n = 2 + r.gen_range(70);
            let m = n + r.gen_range(3 * n);
            let seed = r.gen_range(1 << 30) as u64;
            generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed).to_dense()
        },
        |base| {
            let mut oracle = base.clone();
            fw_inplace(&mut oracle);
            let n = base.n();
            let variants: Vec<(&str, DistMatrix)> = vec![
                ("rowwise", {
                    let mut d = base.clone();
                    fw_rowwise(&mut d);
                    d
                }),
                ("rowwise_scratch", {
                    let mut d = base.clone();
                    let mut row_k = vec![0f32; n];
                    fw_rowwise_scratch(&mut d, &mut row_k);
                    d
                }),
                ("parallel", {
                    let mut d = base.clone();
                    fw_parallel(&mut d);
                    d
                }),
                ("panel", {
                    let mut d = base.clone();
                    fw_panel(&mut d);
                    d
                }),
                ("panel_scratch", {
                    let mut d = base.clone();
                    let (mut pr, mut pc) = (vec![0f32; n], vec![0f32; n]);
                    fw_panel_scratch(&mut d, &mut pr, &mut pc);
                    d
                }),
            ];
            for (name, got) in &variants {
                if oracle.max_diff(got) != 0.0 {
                    return Err(format!("fw_{name} != fw_inplace (n={n})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn minplus_blocked_bit_identical_to_scalar() {
    // blocked 4-row microkernel and the parallel splitter vs the scalar
    // oracle and a naive reference, across ragged (m, k, n) incl. the
    // quad remainder rows and empty inner dims
    assert_prop(
        60,
        |r| {
            let (m, k, n) = (
                1 + r.gen_range(18),
                1 + r.gen_range(18),
                1 + r.gen_range(18),
            );
            let mut rr = r.fork();
            let a = rand_row(&mut rr, m * k, 0.25);
            let b = rand_row(&mut rr, k * n, 0.25);
            let c0 = rand_row(&mut rr, m * n, 0.5);
            (a, b, c0, (m, k, n))
        },
        |(a, b, c0, (m, k, n))| {
            let (m, k, n) = (*m, *k, *n);
            let mut naive = c0.clone();
            for i in 0..m {
                for kk in 0..k {
                    let aik = a[i * k + kk];
                    if aik >= INF {
                        continue;
                    }
                    for j in 0..n {
                        let cand = aik + b[kk * n + j];
                        if cand < naive[i * n + j] {
                            naive[i * n + j] = cand;
                        }
                    }
                }
            }
            let mut scalar = c0.clone();
            minplus_into_scalar(&mut scalar, a, b, m, k, n);
            let mut blocked = c0.clone();
            minplus_into(&mut blocked, a, b, m, k, n);
            let mut par = c0.clone();
            minplus_into_parallel(&mut par, a, b, m, k, n);
            if !bits_eq(&scalar, &naive) {
                return Err(format!("scalar oracle != naive ({m}x{k}x{n})"));
            }
            if !bits_eq(&blocked, &scalar) {
                return Err(format!("blocked minplus != scalar ({m}x{k}x{n})"));
            }
            if !bits_eq(&par, &scalar) {
                return Err(format!("parallel minplus != scalar ({m}x{k}x{n})"));
            }
            Ok(())
        },
    );
}

#[test]
fn fw_blocked_backends_agree_on_ragged_edges() {
    // non-divisible block edges: the scalar-pinned and SIMD-dispatching
    // backends must compose fw_blocked **bit-identically** (same op
    // order, bit-equal primitives); the blocked result itself is only
    // tolerance-close to the direct solve (Katz–Kider reassociates)
    assert_prop(
        8,
        |r| {
            let n = 20 + r.gen_range(110);
            let block = 8 + r.gen_range(40);
            let m = 2 * n + r.gen_range(2 * n);
            let seed = r.gen_range(1 << 30) as u64;
            let d = generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed).to_dense();
            (d, block)
        },
        |(base, block)| {
            let (n, block) = (base.n(), *block);
            let mut via_scalar = base.clone();
            fw_blocked(&ScalarBackend, &mut via_scalar, block);
            let mut via_simd = base.clone();
            fw_blocked(&SimdBackend, &mut via_simd, block);
            if via_scalar.max_diff(&via_simd) != 0.0 {
                return Err(format!(
                    "fw_blocked scalar vs simd diverged (n={n} block={block})"
                ));
            }
            let mut direct = base.clone();
            fw_inplace(&mut direct);
            let diff = direct.max_diff(&via_scalar);
            if diff < 1e-4 {
                Ok(())
            } else {
                Err(format!(
                    "fw_blocked off by {diff} vs direct (n={n} block={block})"
                ))
            }
        },
    );
}

#[test]
fn all_backends_agree_bitwise() {
    let g = generators::random_connected(96, 300, Weights::Uniform(0.5, 4.0), 77);
    let base = g.to_dense();
    let mut oracle = base.clone();
    ScalarBackend.fw(&mut oracle);
    for be in [
        &SerialBackend as &dyn TileBackend,
        &SimdBackend,
        &NativeBackend,
    ] {
        let mut d = base.clone();
        be.fw(&mut d);
        assert_eq!(oracle.max_diff(&d), 0.0, "fw backend {}", be.name());
    }
    let mut rng = Rng::new(78);
    let (m, k, n) = (41usize, 23usize, 37usize);
    let a = rand_row(&mut rng, m * k, 0.3);
    let b = rand_row(&mut rng, k * n, 0.0);
    let mut c_oracle = vec![INF; m * n];
    ScalarBackend.minplus_into(&mut c_oracle, &a, &b, m, k, n);
    for be in [
        &SerialBackend as &dyn TileBackend,
        &SimdBackend,
        &NativeBackend,
    ] {
        let mut c = vec![INF; m * n];
        be.minplus_into(&mut c, &a, &b, m, k, n);
        assert!(bits_eq(&c, &c_oracle), "minplus backend {}", be.name());
    }
}

// ---- tile arena invariants ----

#[test]
fn arena_never_serves_one_buffer_to_two_live_leases() {
    assert_prop(
        20,
        |r| {
            let sizes: Vec<usize> = (0..(2 + r.gen_range(30)))
                .map(|_| 1 + r.gen_range(500))
                .collect();
            sizes
        },
        |sizes| {
            let mut arena = TileArena::new();
            // interleave: lease half, recycle some, lease the rest —
            // every simultaneously-live buffer must be distinct storage
            let mut live: Vec<Vec<f32>> = Vec::new();
            for (i, &len) in sizes.iter().enumerate() {
                live.push(arena.lease_filled(len, 0.0));
                if i % 3 == 2 {
                    let buf = live.remove(0);
                    arena.recycle(buf);
                }
                let mut ptrs: Vec<usize> =
                    live.iter().map(|b| b.as_ptr() as usize).collect();
                ptrs.sort_unstable();
                ptrs.dedup();
                if ptrs.len() != live.len() {
                    return Err("two live leases share a backing store".into());
                }
            }
            let stats = arena.stats();
            if stats.live != live.len() {
                return Err(format!(
                    "live accounting off: {} tracked vs {} held",
                    stats.live,
                    live.len()
                ));
            }
            for buf in live.drain(..) {
                arena.recycle(buf);
            }
            if arena.stats().live != 0 {
                return Err("live count nonzero after recycling everything".into());
            }
            Ok(())
        },
    );
}

#[test]
fn arena_high_water_bounded_by_plan_census() {
    // replay a DAG run's slot lifecycle against a private pool: lease
    // one buffer per census entry (the worst case — every slot live at
    // once), and check (a) the census accounting matches the plan, and
    // (b) a second run is served entirely from the pool (alloc plateau)
    let g = generators::ogbn_proxy(400, 10.0, Weights::Uniform(1.0, 3.0), 41);
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 48,
            max_depth: usize::MAX,
            seed: 41,
        },
    );
    let census_elems = plan_tile_census(&plan);

    // enumerate the slot sizes exactly as plan_tile_census counts them
    let depth = plan.depth();
    let mut sizes: Vec<usize> = vec![plan.final_n * plan.final_n];
    for (l, lvl) in plan.levels.iter().enumerate() {
        for c in &lvl.cs.components {
            sizes.push(c.n() * c.n());
        }
        sizes.push(if l + 1 < depth {
            plan.levels[l + 1].n * plan.levels[l + 1].n
        } else {
            plan.final_n * plan.final_n
        });
    }
    assert_eq!(
        sizes.iter().sum::<usize>(),
        census_elems,
        "census enumeration drifted from plan_tile_census"
    );

    let mut arena = TileArena::new();
    let run = |arena: &mut TileArena| {
        let live: Vec<Vec<f32>> = sizes.iter().map(|&s| arena.lease_filled(s, 0.0)).collect();
        assert!(
            arena.stats().high_water <= sizes.len(),
            "high water {} exceeds census slot count {}",
            arena.stats().high_water,
            sizes.len()
        );
        for buf in live {
            arena.recycle(buf);
        }
    };
    run(&mut arena);
    let allocs_after_first = arena.stats().allocs;
    run(&mut arena);
    assert_eq!(
        arena.stats().allocs,
        allocs_after_first,
        "second run should be allocation-free (full pool reuse)"
    );
    assert_eq!(arena.stats().live, 0);
}

// ---- semiring engine properties ----

/// Domain-valid random elements for `sr`: a `zero_frac` share of
/// ⊕-identity ("no path") cells, the rest mapped from positive edge
/// weights through `from_weight` — the same path `to_dense_sr` takes.
fn rand_elems(rng: &mut Rng, sr: SemiringId, len: usize, zero_frac: f64) -> Vec<f32> {
    (0..len)
        .map(|_| {
            if rng.gen_bool(zero_frac) {
                sr.zero()
            } else {
                sr.from_weight(rng.gen_f32_range(0.5, 4.0))
            }
        })
        .collect()
}

/// Naive ⊕/⊗ accumulating product — the scalar oracle the generic
/// kernels are held bit-identical to. ⊕ is an exact selection (min /
/// max / and-or) and ⊗ candidates are computed pairwise, so the
/// reduction order cannot perturb bits.
fn naive_product(
    sr: SemiringId,
    c0: &[f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut c = c0.to_vec();
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk];
            if sr.is_absorbing(aik) {
                continue;
            }
            for j in 0..n {
                let cand = sr.extend(aik, b[kk * n + j]);
                c[i * n + j] = sr.combine(c[i * n + j], cand);
            }
        }
    }
    c
}

/// Naive in-place ⊕/⊗ FW closure (triple loop) — the per-semiring
/// scalar oracle for the dispatched row-wise and parallel kernels.
fn naive_closure(d: &mut DistMatrix, sr: SemiringId) {
    let n = d.n();
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if sr.is_absorbing(dik) {
                continue;
            }
            for j in 0..n {
                let via = sr.extend(dik, d.get(k, j));
                d.set(i, j, sr.combine(d.get(i, j), via));
            }
        }
    }
}

#[test]
fn semiring_fw_dyn_bit_identical_to_naive_closure() {
    // all four instances; MaxPlus runs on the DAG orientation (its
    // closure has no fixed point on cycles)
    assert_prop(
        8,
        |r| {
            let n = 2 + r.gen_range(60);
            let m = n + r.gen_range(3 * n);
            let seed = r.gen_range(1 << 30) as u64;
            generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed)
        },
        |g| {
            let dag = g.dag_oriented();
            for sr in ALL_SEMIRINGS {
                let src = if sr == SemiringId::MaxPlus { &dag } else { g };
                let base = src.to_dense_sr(sr);
                let n = base.n();
                let mut oracle = base.clone();
                naive_closure(&mut oracle, sr);
                let mut rowwise = base.clone();
                fw_rowwise_dyn(&mut rowwise, sr);
                let mut par = base.clone();
                fw_parallel_dyn(&mut par, sr);
                if !bits_eq(rowwise.as_slice(), oracle.as_slice()) {
                    return Err(format!("{} fw_rowwise_dyn != naive closure (n={n})", sr.name()));
                }
                if !bits_eq(par.as_slice(), oracle.as_slice()) {
                    return Err(format!("{} fw_parallel_dyn != naive closure (n={n})", sr.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn semiring_product_dyn_bit_identical_to_naive() {
    // ragged (m, k, n) per instance, with ⊕-identity cells exercising
    // the is_absorbing early-out
    assert_prop(
        40,
        |r| {
            let dims = (1 + r.gen_range(14), 1 + r.gen_range(14), 1 + r.gen_range(14));
            let sr = ALL_SEMIRINGS[r.gen_range(ALL_SEMIRINGS.len())];
            let mut rr = r.fork();
            let a = rand_elems(&mut rr, sr, dims.0 * dims.1, 0.25);
            let b = rand_elems(&mut rr, sr, dims.1 * dims.2, 0.25);
            let c0 = rand_elems(&mut rr, sr, dims.0 * dims.2, 0.5);
            (sr, a, b, c0, dims)
        },
        |(sr, a, b, c0, (m, k, n))| {
            let (sr, m, k, n) = (*sr, *m, *k, *n);
            let oracle = naive_product(sr, c0, a, b, m, k, n);
            let mut got = c0.clone();
            product_into_dyn(sr, &mut got, a, b, m, k, n);
            if bits_eq(&got, &oracle) {
                Ok(())
            } else {
                Err(format!("{} product_into_dyn != naive ({m}x{k}x{n})", sr.name()))
            }
        },
    );
}

#[test]
fn reachability_closure_matches_bfs_oracle() {
    // sparse random (often disconnected) undirected graphs: the
    // bool-and-or closure must agree with per-source BFS exactly
    assert_prop(
        10,
        |r| {
            let n = 3 + r.gen_range(60);
            let m = r.gen_range(2 * n);
            let mut rr = r.fork();
            let edges: Vec<(u32, u32, f32)> = (0..m)
                .map(|_| {
                    let u = rr.gen_range(n) as u32;
                    let v = rr.gen_range(n) as u32;
                    (u, v, rr.gen_f32_range(0.5, 4.0))
                })
                .filter(|&(u, v, _)| u != v)
                .collect();
            CsrGraph::from_undirected_edges(n, &edges)
        },
        |g| {
            let sr = SemiringId::BoolAndOr;
            let mut d = g.to_dense_sr(sr);
            fw_rowwise_dyn(&mut d, sr);
            let n = g.n();
            for s in 0..n {
                let mut seen = vec![false; n];
                let mut queue = vec![s];
                seen[s] = true;
                while let Some(u) = queue.pop() {
                    for (v, _) in g.neighbors(u) {
                        if !seen[v] {
                            seen[v] = true;
                            queue.push(v);
                        }
                    }
                }
                for (t, &reach) in seen.iter().enumerate() {
                    let got = !sr.is_absorbing(d.get(s, t));
                    if got != reach {
                        return Err(format!(
                            "reach({s},{t}) = {got} but BFS says {reach} (n={n})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Modified Dijkstra for widest path: repeatedly settle the unsettled
/// vertex of maximum bottleneck width, relaxing `min(width[u], w)`
/// through `max`. O(n²) selection keeps it heap-free (and therefore
/// trivially exact — every value is a min/max selection over edge
/// weights, never arithmetic).
fn widest_oracle(g: &CsrGraph, s: usize) -> Vec<f32> {
    let n = g.n();
    let mut width = vec![0.0f32; n];
    let mut done = vec![false; n];
    width[s] = f32::INFINITY;
    loop {
        let mut best: Option<usize> = None;
        for v in 0..n {
            if !done[v] && width[v] > 0.0 && best.map_or(true, |b| width[v] > width[b]) {
                best = Some(v);
            }
        }
        let Some(u) = best else { break };
        done[u] = true;
        for (v, w) in g.neighbors(u) {
            let cand = width[u].min(w);
            if cand > width[v] {
                width[v] = cand;
            }
        }
    }
    width
}

#[test]
fn widest_path_closure_matches_modified_dijkstra() {
    assert_prop(
        8,
        |r| {
            let n = 4 + r.gen_range(50);
            let m = n + r.gen_range(3 * n);
            let seed = r.gen_range(1 << 30) as u64;
            generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed)
        },
        |g| {
            let sr = SemiringId::MaxMin;
            let mut d = g.to_dense_sr(sr);
            fw_parallel_dyn(&mut d, sr);
            let n = g.n();
            for s in (0..n).step_by(5) {
                let width = widest_oracle(g, s);
                for (t, &w) in width.iter().enumerate() {
                    if d.get(s, t).to_bits() != w.to_bits() {
                        return Err(format!(
                            "widest({s},{t}) = {} but Dijkstra oracle says {w} (n={n})",
                            d.get(s, t)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Verbatim freeze of the pre-refactor scalar `(min,+)` kernels —
/// the triple-loop FW and the row-at-a-time min-plus accumulate exactly
/// as they stood before the semiring generalization. The generic engine
/// pinned to `SemiringId::MinPlus` must reproduce them bit-for-bit:
/// this is the ISSUE's "`--workload apsp` is bit-identical" acceptance
/// pinned at the kernel layer.
fn frozen_minplus_fw(d: &mut DistMatrix) {
    let n = d.n();
    for k in 0..n {
        for i in 0..n {
            let dik = d.get(i, k);
            if !(dik < f32::INFINITY) {
                continue;
            }
            for j in 0..n {
                let cand = dik + d.get(k, j);
                if cand < d.get(i, j) {
                    d.set(i, j, cand);
                }
            }
        }
    }
}

fn frozen_minplus_product(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk];
            if !(aik < f32::INFINITY) {
                continue;
            }
            for j in 0..n {
                let cand = aik + b[kk * n + j];
                if cand < c[i * n + j] {
                    c[i * n + j] = cand;
                }
            }
        }
    }
}

#[test]
fn minplus_generic_bit_identical_to_frozen_prerefactor_kernels() {
    assert_prop(
        10,
        |r| {
            let n = 2 + r.gen_range(60);
            let m = n + r.gen_range(3 * n);
            let seed = r.gen_range(1 << 30) as u64;
            generators::random_connected(n, m, Weights::Uniform(0.5, 4.0), seed)
        },
        |g| {
            // dense materialization must not have drifted either
            let base = g.to_dense();
            let base_sr = g.to_dense_sr(SemiringId::MinPlus);
            if !bits_eq(base_sr.as_slice(), base.as_slice()) {
                return Err("to_dense_sr(MinPlus) != to_dense".into());
            }
            let n = base.n();
            let mut frozen = base.clone();
            frozen_minplus_fw(&mut frozen);
            let mut dyn_fw = base.clone();
            fw_rowwise_dyn(&mut dyn_fw, SemiringId::MinPlus);
            if !bits_eq(dyn_fw.as_slice(), frozen.as_slice()) {
                return Err(format!("fw_rowwise_dyn(MinPlus) != frozen kernel (n={n})"));
            }
            // accumulating product on slices of the closed matrix
            let a = frozen.as_slice().to_vec();
            let mut c_frozen = base.as_slice().to_vec();
            frozen_minplus_product(&mut c_frozen, &a, &a, n, n, n);
            let mut c_dyn = base.as_slice().to_vec();
            product_into_dyn(SemiringId::MinPlus, &mut c_dyn, &a, &a, n, n, n);
            if !bits_eq(&c_dyn, &c_frozen) {
                return Err(format!("product_into_dyn(MinPlus) != frozen kernel (n={n})"));
            }
            Ok(())
        },
    );
}
