//! Property tests on coordinator/trace invariants — the "scheduler never
//! double-books, never drops work" class of guarantees (DESIGN.md
//! testing strategy), checked over randomized workloads via the seeded
//! property harness.

use rapid_graph::apsp::plan::{build_plan, PlanOptions};
use rapid_graph::apsp::recursive::{solve, SolveOptions};
use rapid_graph::apsp::trace::{Op, Phase, Trace};
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::sim::engine::simulate;
use rapid_graph::sim::params::HwParams;
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;

fn random_workload(r: &mut Rng) -> (CsrGraph, usize, u64) {
    let topo = [Topology::Nws, Topology::Er, Topology::OgbnProxy, Topology::Grid]
        [r.gen_range(4)];
    let n = 200 + r.gen_range(1500);
    let deg = 4.0 + r.gen_f64() * 16.0;
    let seed = r.next_u64();
    let tile = [32usize, 64, 128, 256][r.gen_range(4)];
    (
        generators::generate(topo, n, deg, Weights::Uniform(0.5, 5.0), seed),
        tile,
        seed,
    )
}

fn trace_of(g: &CsrGraph, tile: usize, seed: u64) -> (Trace, rapid_graph::apsp::plan::ApspPlan) {
    let plan = build_plan(
        g,
        PlanOptions {
            tile_limit: tile,
            max_depth: usize::MAX,
            seed,
        },
    );
    let sol = solve(g, &plan, None, SolveOptions::default());
    (sol.trace, plan)
}

#[test]
fn every_component_loaded_and_solved_exactly_once_per_level() {
    assert_prop(15, random_workload, |(g, tile, seed)| {
        let (trace, plan) = trace_of(g, *tile, *seed);
        for (li, lvl) in plan.levels.iter().enumerate() {
            let nonempty = lvl.cs.components.iter().filter(|c| c.n() > 0).count();
            let loads: usize = trace
                .steps
                .iter()
                .filter(|s| s.level == li as u32 && s.phase == Phase::Load)
                .map(|s| s.ops.len())
                .sum();
            if loads != nonempty {
                return Err(format!(
                    "level {li}: {loads} loads for {nonempty} components"
                ));
            }
            let solvable = lvl.cs.components.iter().filter(|c| c.n() > 1).count();
            let fws: usize = trace
                .steps
                .iter()
                .filter(|s| s.level == li as u32 && s.phase == Phase::LocalFw)
                .map(|s| s.ops.len())
                .sum();
            if fws != solvable {
                return Err(format!("level {li}: {fws} FW ops for {solvable} components"));
            }
        }
        Ok(())
    });
}

#[test]
fn injection_matches_boundary_components() {
    assert_prop(15, random_workload, |(g, tile, seed)| {
        let (trace, plan) = trace_of(g, *tile, *seed);
        for (li, lvl) in plan.levels.iter().enumerate() {
            if lvl.n_boundary() == 0 {
                continue;
            }
            let with_boundary = lvl
                .cs
                .components
                .iter()
                .filter(|c| c.n_boundary > 0)
                .count();
            let injects: usize = trace
                .steps
                .iter()
                .filter(|s| s.level == li as u32 && s.phase == Phase::Inject)
                .map(|s| s.ops.len())
                .sum();
            if injects != with_boundary {
                return Err(format!(
                    "level {li}: {injects} injects vs {with_boundary} boundary comps"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn op_sizes_respect_tile_limit() {
    assert_prop(15, random_workload, |(g, tile, seed)| {
        let (trace, plan) = trace_of(g, *tile, *seed);
        for step in &trace.steps {
            for op in &step.ops {
                if let Op::TileFw { n, .. } = op {
                    // only the terminal solve may exceed the tile limit
                    let terminal = step.phase == Phase::FinalSolve;
                    if !terminal && *n as usize > *tile {
                        return Err(format!(
                            "non-terminal FW of size {n} > tile {tile} at level {}",
                            step.level
                        ));
                    }
                    if terminal && *n as usize != plan.final_n {
                        return Err("terminal FW size != plan.final_n".into());
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn simulated_cost_deterministic_and_additive() {
    assert_prop(10, random_workload, |(g, tile, seed)| {
        let (trace, _) = trace_of(g, *tile, *seed);
        let p = HwParams::default();
        let a = simulate(&trace, &p);
        let b = simulate(&trace, &p);
        if a.seconds != b.seconds || a.joules != b.joules {
            return Err("simulation not deterministic".into());
        }
        let phase_sum: f64 = a.per_phase.values().map(|s| s.secs).sum();
        if (phase_sum - a.seconds).abs() > 1e-9 {
            return Err(format!("phases {phase_sum} != total {}", a.seconds));
        }
        if a.fw_busy > a.seconds + 1e-12 || a.mp_busy > a.seconds + 1e-12 {
            return Err("resource busy exceeds wall time".into());
        }
        Ok(())
    });
}

#[test]
fn madds_match_plan_structure() {
    // total FW madds must equal sum over levels of components' n^3 (+
    // rerun) + terminal; a mismatch means dropped or duplicated work
    assert_prop(10, random_workload, |(g, tile, seed)| {
        let (trace, plan) = trace_of(g, *tile, *seed);
        let mut expect: u64 = 0;
        for lvl in &plan.levels {
            for c in &lvl.cs.components {
                let n = c.n() as u64;
                if c.n() > 1 {
                    expect += n * n * n; // local FW
                    if c.n_boundary > 0 && lvl.n_boundary() > 0 {
                        expect += n * n * n; // rerun after injection
                    }
                }
            }
        }
        let fnl = plan.final_n as u64;
        if fnl > 1 {
            expect += fnl * fnl * fnl;
        }
        let fw_madds: u64 = trace
            .steps
            .iter()
            .flat_map(|s| s.ops.iter())
            .filter_map(|op| match op {
                Op::TileFw { n, .. } => Some(n * n * n),
                _ => None,
            })
            .sum();
        if fw_madds != expect {
            return Err(format!("FW madds {fw_madds} != expected {expect}"));
        }
        Ok(())
    });
}

#[test]
fn deeper_recursion_never_increases_terminal_size() {
    assert_prop(10, random_workload, |(g, tile, seed)| {
        let full = build_plan(
            g,
            PlanOptions {
                tile_limit: *tile,
                max_depth: usize::MAX,
                seed: *seed,
            },
        );
        let alg1 = build_plan(
            g,
            PlanOptions {
                tile_limit: *tile,
                max_depth: 1,
                seed: *seed,
            },
        );
        if full.final_n > alg1.final_n {
            return Err(format!(
                "recursion made the terminal bigger: {} > {}",
                full.final_n, alg1.final_n
            ));
        }
        Ok(())
    });
}
