//! Failure-injection tests: corrupted artifacts, malformed inputs, and
//! misconfiguration must fail loudly and informatively, never silently.

use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::io;
use rapid_graph::runtime::Manifest;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("rapid_failure_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[cfg(feature = "pjrt")]
#[test]
fn corrupted_hlo_artifact_fails_at_load() {
    use rapid_graph::runtime::PjrtRuntime;
    let dir = tmpdir("bad_hlo");
    std::fs::write(dir.join("fw_block_64.hlo.txt"), "this is not HLO").unwrap();
    std::fs::write(dir.join("minplus_64.hlo.txt"), "nor is this").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"artifacts": [
            {"kind": "fw", "n": 64, "path": "fw_block_64.hlo.txt"},
            {"kind": "minplus", "n": 64, "path": "minplus_64.hlo.txt"}
        ]}"#,
    )
    .unwrap();
    let err = match PjrtRuntime::load(&dir) {
        Ok(_) => panic!("corrupted HLO must not load"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("fw_block_64"), "error should name the file: {msg}");
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_unavailable_without_feature() {
    // without the `pjrt` cargo feature the runtime must fail loudly at
    // load time (never silently fall back to native numerics)
    let err = rapid_graph::runtime::PjrtRuntime::load_default().unwrap_err();
    assert!(format!("{err}").contains("pjrt"), "error must name the feature: {err}");
    let mut cfg = SystemConfig::default();
    cfg.backend = rapid_graph::coordinator::config::BackendKind::Pjrt;
    assert!(Executor::new(cfg).is_err(), "pjrt backend must not construct");
}

#[test]
fn truncated_manifest_rejected() {
    let dir = tmpdir("bad_manifest");
    std::fs::write(dir.join("manifest.json"), r#"{"artifacts": ["#).unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn malformed_edge_list_rejected() {
    let dir = tmpdir("bad_edges");
    let p = dir.join("g.txt");
    std::fs::write(&p, "3 1\n0 notanumber 1.0\n").unwrap();
    assert!(io::read_edge_list(&p).is_err());
}

#[test]
fn out_of_range_edge_panics_in_builder() {
    let result = std::panic::catch_unwind(|| {
        CsrGraph::from_edges(2, &[(0, 5, 1.0)]);
    });
    assert!(result.is_err(), "edge target 5 in a 2-vertex graph must panic");
}

#[test]
fn csr_validate_catches_corruption() {
    let mut g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (1, 2, 1.0)]);
    g.val[0] = -3.0; // negative weight
    assert!(g.validate().is_err());
    let mut g2 = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0)]);
    g2.rowptr[1] = 99; // broken rowptr
    assert!(g2.validate().is_err());
}

#[test]
fn memory_guard_rejects_oversized_functional_runs() {
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        3000,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        1,
    );
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 128;
    cfg.memory_limit_bytes = 1 << 20; // 1 MiB: far too small
    let ex = Executor::new(cfg).unwrap();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| ex.run(&g)));
    assert!(res.is_err(), "memory guard must trip");
}

#[test]
fn misconstructed_pjrt_executor_errors_cleanly() {
    // An Executor constructed for native numerics whose config is then
    // switched to the pjrt backend has no loaded runtime. Running it
    // must return a clean error naming the problem — not panic.
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    let mut ex = Executor::new(cfg).unwrap();
    ex.config.backend = rapid_graph::coordinator::config::BackendKind::Pjrt;
    let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
    let err = match ex.run(&g) {
        Ok(_) => panic!("misconstructed pjrt executor must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("pjrt"),
        "error must name the backend: {err}"
    );
    // the batch path fails the same way
    let err = match ex.run_batch(std::slice::from_ref(&g)) {
        Ok(_) => panic!("misconstructed pjrt executor must not run_batch"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("pjrt"), "{err}");
}

#[test]
fn empty_batch_rejected_cleanly() {
    // a zero-graph batch has no makespan to schedule — it must be a
    // clean error, never a NaN batch_speedup (0/0)
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let err = match ex.run_batch(&[]) {
        Ok(_) => panic!("empty batch must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("at least one graph"),
        "error must explain the empty batch: {err}"
    );
}

#[test]
fn empty_graph_in_batch_rejected_cleanly() {
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let good = CsrGraph::from_undirected_edges(3, &[(0, 1, 1.0), (1, 2, 1.0)]);
    let empty = CsrGraph::from_edges(0, &[]);
    let err = match ex.run_batch(&[good, empty]) {
        Ok(_) => panic!("a 0-vertex graph contributes no schedulable work"),
        Err(e) => e,
    };
    let msg = format!("{err}");
    assert!(msg.contains("empty"), "error must name the problem: {msg}");
    assert!(msg.contains("1"), "error should say which graph: {msg}");
}

#[test]
fn zero_stacks_rejected_cleanly() {
    // --stacks 0 / run.num_stacks = 0 must be a clean error, not a
    // panic somewhere inside the shard lowering
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    cfg.num_stacks = 0;
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        200,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        1,
    );
    let err = match ex.run_sharded(&g) {
        Ok(_) => panic!("0 stacks must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("num_stacks"),
        "error must name the knob: {err}"
    );
}

#[test]
fn more_stacks_than_tiles_rejected_cleanly() {
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    cfg.num_stacks = 4096; // far above any tile count of a 200-vertex graph
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        200,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        1,
    );
    let err = match ex.run_sharded(&g) {
        Ok(_) => panic!("stacks > tile count must not run"),
        Err(e) => e,
    };
    let msg = format!("{err}");
    assert!(msg.contains("tile"), "error must explain the bound: {msg}");
}

#[test]
fn apsp_mode_flags_mutually_exclusive() {
    // the CLI used to tolerate `--batch --stacks 1` silently; every
    // pairing of the mode-selecting flags must now be a clean
    // util::error, and single-mode invocations still resolve
    use rapid_graph::coordinator::config::{resolve_cli_mode, CliMode};
    use rapid_graph::util::cli::Args;
    let parse = |v: &[&str]| Args::parse(v.iter().map(|s| s.to_string()));
    for combo in [
        vec!["--batch", "--stacks", "4"],
        vec!["--batch", "--stacks", "1"],
        vec!["--batch", "--admit"],
        vec!["--admit", "6", "--stacks", "2"],
        vec!["--graphs", "a.bin,b.bin", "--stacks", "2"],
        vec!["--batch", "3", "--admit", "2", "--stacks", "2"],
        vec!["--deltas", "d.txt", "--batch"],
        vec!["--deltas", "d.txt", "--stacks", "2"],
        vec!["--deltas", "d.txt", "--admit", "2"],
        vec!["--serve", "--batch"],
        vec!["--serve", "--stacks", "2"],
        vec!["--serve", "--admit"],
        vec!["--queries", "q.txt", "--batch"],
        vec!["--queries", "q.txt", "--admit", "2"],
    ] {
        let err = resolve_cli_mode(&parse(&combo), 1).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pick one"), "{combo:?} must conflict: {msg}");
        assert!(msg.contains("--"), "{combo:?}: message should name the flags: {msg}");
    }
    assert_eq!(
        resolve_cli_mode(&parse(&["--deltas", "d.txt"]), 1).unwrap(),
        CliMode::Delta
    );
    assert_eq!(
        resolve_cli_mode(&parse(&["--serve", "--queries", "q.txt"]), 1).unwrap(),
        CliMode::Serve
    );
    // the delta feed composes with serve: it is the mutation stream
    // between query batches, not a competing mode
    assert_eq!(
        resolve_cli_mode(&parse(&["--serve", "--deltas", "d.txt"]), 1).unwrap(),
        CliMode::Serve
    );
    assert_eq!(resolve_cli_mode(&parse(&["--batch"]), 1).unwrap(), CliMode::Batch);
    assert_eq!(
        resolve_cli_mode(&parse(&["--stacks", "4"]), 1).unwrap(),
        CliMode::Sharded
    );
    assert_eq!(
        resolve_cli_mode(&parse(&["--admit"]), 1).unwrap(),
        CliMode::Admission
    );
    assert_eq!(resolve_cli_mode(&parse(&[]), 1).unwrap(), CliMode::Solo);
}

#[test]
fn admission_zero_queue_depth_rejected_cleanly() {
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.admission_queue_depth = 0;
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        100,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        1,
    );
    let err = match ex.run_admission(std::slice::from_ref(&g)) {
        Ok(_) => panic!("queue depth 0 must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("queue_depth"),
        "error must name the knob: {err}"
    );
}

#[test]
fn admission_rejections_are_clean_and_nonfatal() {
    // an empty graph and an over-capacity graph arrive mid-stream:
    // both are turned away with named verdicts while every other
    // submission is served
    use rapid_graph::apsp::admission::{RejectReason, Verdict};
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.tile_limit = 64;
    cfg.memory_limit_bytes = 4 << 20;
    cfg.admission_interval = 1e-4;
    let ex = Executor::new(cfg).unwrap();
    let gen = |n: usize, seed: u64| {
        rapid_graph::graph::generators::newman_watts_strogatz(
            n,
            4,
            0.1,
            rapid_graph::graph::generators::Weights::Unit,
            seed,
        )
    };
    let graphs = vec![
        gen(150, 1),
        CsrGraph::from_edges(0, &[]),
        gen(6_000, 2),
        gen(200, 3),
    ];
    let a = ex.run_admission(&graphs).unwrap();
    assert_eq!(a.n_admitted(), 2);
    assert_eq!(a.n_rejected(), 2);
    assert_eq!(
        a.per_graph[1].verdict,
        Verdict::Rejected(RejectReason::Empty)
    );
    assert_eq!(
        a.per_graph[2].verdict,
        Verdict::Rejected(RejectReason::StackCapacity)
    );
    assert!(a.per_graph[0].verdict.admitted());
    assert!(a.per_graph[3].verdict.admitted(), "pipeline keeps running");
}

#[test]
fn store_capacity_zero_disables_cleanly() {
    // `run.store.capacity = 0` must mean "store off": every submission
    // is an uncached miss, nothing errors, and the pipeline result is
    // still fully populated
    use rapid_graph::apsp::admission::StoreOutcome;
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.admission_interval = 1e-4;
    cfg.store_enabled = true;
    cfg.store_capacity = 0;
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        200,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        1,
    );
    let graphs = vec![g.clone(), g];
    let a = ex.run_admission(&graphs).unwrap();
    assert_eq!(a.n_admitted(), 2);
    assert_eq!(a.n_store_hits(), 0, "a disabled store can never hit");
    for r in &a.per_graph {
        assert_eq!(r.store, Some(StoreOutcome::MissUncached));
        assert!(r.latency > 0.0);
    }
}

#[test]
fn store_capacity_one_evicts_deterministically() {
    // capacity 1 is the degenerate LRU: every distinct put evicts the
    // sole resident, repeatably
    use rapid_graph::apsp::store::{MemoryStore, ResultStore, StoreEntry};
    let run = || {
        let mut s = MemoryStore::new(1, u64::MAX);
        let mut residents = Vec::new();
        for key in [7u64, 3, 9, 3] {
            s.put(key, StoreEntry::new(16, key as f64, None)).unwrap();
            assert_eq!(s.len(), 1, "capacity 1 holds exactly one entry");
            assert!(s.contains(key), "latest put must be resident");
            residents.push(s.keys());
        }
        residents
    };
    let a = run();
    assert_eq!(a, run(), "eviction must be deterministic");
    assert_eq!(a.last().unwrap(), &vec![3u64]);
}

#[test]
fn oversized_store_entry_rejected_without_mass_eviction() {
    // an entry that alone exceeds the byte budget must be a clean
    // util::error that leaves the resident set untouched — never a
    // panic, never "evict everything then fail anyway"
    use rapid_graph::apsp::store::{MemoryStore, ResultStore, StoreEntry};
    let mut s = MemoryStore::new(8, 1_000);
    s.put(1, StoreEntry::new(400, 1.0, None)).unwrap();
    s.put(2, StoreEntry::new(400, 2.0, None)).unwrap();
    let err = s.put(3, StoreEntry::new(1_001, 99.0, None)).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("exceeds the store byte budget"),
        "error must explain the rejection: {msg}"
    );
    assert!(s.contains(1) && s.contains(2), "nothing may be evicted");
    assert_eq!(s.bytes_used(), 800);
}

#[test]
fn over_budget_store_keeps_admission_running_uncached() {
    // end-to-end: a byte budget too small for any result degrades to
    // uncached misses while every submission is still served
    use rapid_graph::apsp::admission::StoreOutcome;
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.admission_interval = 1e-4;
    cfg.store_enabled = true;
    cfg.store_capacity = 8;
    cfg.store_bytes = 64; // far below any n x n result payload
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        200,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Unit,
        2,
    );
    let graphs = vec![g.clone(), g];
    let a = ex.run_admission(&graphs).unwrap();
    assert_eq!(a.n_admitted(), 2);
    assert_eq!(a.n_store_hits(), 0);
    for r in &a.per_graph {
        assert_eq!(r.store, Some(StoreOutcome::MissUncached));
    }
}

#[test]
fn store_capacity_flag_conflicts_with_non_admission_modes() {
    // `--store-capacity` rides on the admission pipeline; pairing it
    // with any other mode selector (or no mode at all) must be a clean
    // util::error naming `--admit`
    use rapid_graph::coordinator::config::{resolve_cli_mode, CliMode};
    use rapid_graph::util::cli::Args;
    let parse = |v: &[&str]| Args::parse(v.iter().map(|s| s.to_string()));
    for combo in [
        vec!["--store-capacity", "4"],
        vec!["--batch", "--store-capacity", "4"],
        vec!["--stacks", "2", "--store-capacity", "4"],
    ] {
        let err = resolve_cli_mode(&parse(&combo), 1).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--admit"), "{combo:?} must point at --admit: {msg}");
    }
    assert_eq!(
        resolve_cli_mode(&parse(&["--admit", "--store-capacity", "4"]), 1).unwrap(),
        CliMode::Admission
    );
}

#[test]
fn delta_validation_rejects_malformed_deltas_cleanly() {
    // every malformed delta kind must be a clean util::error that names
    // the offending delta and the rule it broke — never a panic inside
    // the repair engine
    use rapid_graph::apsp::delta::{validate_deltas, EdgeDelta};
    let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
    let cases: Vec<(EdgeDelta, &str)> = vec![
        (EdgeDelta::Insert { u: 0, v: 9, w: 1.0 }, "out of range"),
        (EdgeDelta::Delete { u: 7, v: 1 }, "out of range"),
        (EdgeDelta::Insert { u: 2, v: 2, w: 1.0 }, "self-loop"),
        (
            EdgeDelta::Reweight { u: 0, v: 1, w: f32::NAN },
            "finite and non-negative",
        ),
        (
            EdgeDelta::Reweight { u: 0, v: 1, w: -2.0 },
            "finite and non-negative",
        ),
        (
            EdgeDelta::Insert { u: 0, v: 1, w: f32::INFINITY },
            "finite and non-negative",
        ),
        (EdgeDelta::Insert { u: 0, v: 1, w: 1.0 }, "already exists"),
        (EdgeDelta::Delete { u: 0, v: 3 }, "does not exist"),
        (EdgeDelta::Reweight { u: 0, v: 3, w: 1.0 }, "does not exist"),
    ];
    for (d, needle) in cases {
        let err = validate_deltas(&g, &[d]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains(needle), "{d:?} must fail with {needle:?}: {msg}");
    }
    let err = validate_deltas(&g, &[]).unwrap_err();
    assert!(format!("{err}").contains("empty"), "{err}");
}

#[test]
fn delta_script_parse_failures_are_clean_errors() {
    use rapid_graph::apsp::delta::parse_script;
    let err = parse_script("").unwrap_err();
    assert!(format!("{err}").contains("no deltas"), "{err}");
    let err = parse_script("# comments only\n\n# more\n").unwrap_err();
    assert!(format!("{err}").contains("no deltas"), "{err}");
    let err = parse_script("frobnicate 1 2\n").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("frobnicate"), "error must name the op: {msg}");
    assert!(msg.contains("line 1"), "error must name the line: {msg}");
    let err = parse_script("insert 0 1 2.0 extra\n").unwrap_err();
    assert!(format!("{err}").contains("trailing"), "{err}");
}

#[test]
fn delta_replay_against_unsolved_graph_rejected_cleanly() {
    // a 0-vertex base graph has no solution to repair — the delta
    // engine must refuse it up front with a named error, not panic in
    // the planner
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    let ex = Executor::new(cfg).unwrap();
    let empty = CsrGraph::from_edges(0, &[]);
    let err = match ex.run_delta(&empty, "insert 0 1 1.0\n") {
        Ok(_) => panic!("deltas against an empty base graph must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("base graph"),
        "error must name the problem: {err}"
    );
}

#[test]
fn delta_replay_surfaces_validation_errors_with_batch_context() {
    // run_delta must reject a script whose first batch is fine but
    // whose second batch references a vertex outside the graph
    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let g = rapid_graph::graph::generators::newman_watts_strogatz(
        120,
        4,
        0.1,
        rapid_graph::graph::generators::Weights::Uniform(1.0, 4.0),
        7,
    );
    let (u, v, w) = g.edges().next().unwrap();
    let script = format!("reweight {u} {v} {}\n\ninsert 5 999 1.0\n", w * 0.5);
    let err = match ex.run_delta(&g, &script) {
        Ok(_) => panic!("out-of-range endpoint must not replay"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("out of range"), "error must name the rule: {msg}");
}

#[test]
fn query_script_parse_failures_are_clean_errors() {
    // every malformed query script must be a clean util::error naming
    // the line and the rule it broke — never a panic in the serve loop
    use rapid_graph::apsp::query::parse_query_script;
    let err = parse_query_script("").unwrap_err();
    assert!(format!("{err}").contains("no queries"), "{err}");
    let err = parse_query_script("# comments only\n\n# more\n").unwrap_err();
    assert!(format!("{err}").contains("no queries"), "{err}");
    let err = parse_query_script("frobnicate 1 2\n").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("frobnicate"), "error must name the op: {msg}");
    assert!(msg.contains("line 1"), "error must name the line: {msg}");
    let err = parse_query_script("dist 0\n").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("missing"), "error must name the gap: {msg}");
    let err = parse_query_script("dist 0 notanode\n").unwrap_err();
    assert!(format!("{err}").contains("notanode"), "{err}");
    let err = parse_query_script("path 0 1 2 3\n").unwrap_err();
    assert!(format!("{err}").contains("trailing"), "{err}");
    let err = parse_query_script("dist 0 1 @\n").unwrap_err();
    assert!(format!("{err}").contains("tenant"), "{err}");
    // the error points at the real line, past comments and batch breaks
    let err = parse_query_script("dist 0 1\n\n# batch two\nreach\n").unwrap_err();
    assert!(format!("{err}").contains("line 4"), "{err}");
}

#[test]
fn query_validation_rejects_out_of_range_and_degenerate_k() {
    use rapid_graph::apsp::query::{parse_query_script, validate_queries};
    let script = parse_query_script("dist 0 99\n").unwrap();
    let err = validate_queries(10, &script).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("out of range"), "{msg}");
    assert!(msg.contains("99"), "error must name the node: {msg}");
    let script = parse_query_script("knear 0 0\n").unwrap();
    let err = validate_queries(10, &script).unwrap_err();
    assert!(format!("{err}").contains("degenerate"), "{err}");
    let script = parse_query_script("knear 0 10\n").unwrap();
    let err = validate_queries(10, &script).unwrap_err();
    assert!(format!("{err}").contains("other nodes"), "{err}");
    let script = parse_query_script("dist 0 1\n").unwrap();
    let err = validate_queries(0, &script).unwrap_err();
    assert!(format!("{err}").contains("base graph is empty"), "{err}");
}

#[test]
fn serve_rejects_empty_graph_and_estimate_mode_cleanly() {
    // the serve loop needs functional numerics and a non-empty base
    // graph; both misuses must be clean errors before any state exists
    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let empty = CsrGraph::from_edges(0, &[]);
    let err = match ex.run_serve(&empty, "dist 0 1\n", None) {
        Ok(_) => panic!("serving an empty graph must not run"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("base graph is empty"),
        "error must name the problem: {err}"
    );

    let mut cfg = SystemConfig::default();
    cfg.mode = rapid_graph::coordinator::config::Mode::Estimate;
    cfg.tile_limit = 64;
    let ex = Executor::new(cfg).unwrap();
    let g = CsrGraph::from_undirected_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
    let err = match ex.run_serve(&g, "dist 0 3\n", None) {
        Ok(_) => panic!("estimate mode has no numerics to serve from"),
        Err(e) => e,
    };
    assert!(
        format!("{err}").contains("functional"),
        "error must name the mode requirement: {err}"
    );
}

#[test]
fn binary_graph_roundtrip_detects_truncation() {
    let dir = tmpdir("trunc_bin");
    let g = rapid_graph::graph::generators::erdos_renyi(
        50,
        100,
        rapid_graph::graph::generators::Weights::Unit,
        3,
    );
    let p = dir.join("g.bin");
    io::write_binary(&g, &p).unwrap();
    // chop the file
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    assert!(io::read_binary(&p).is_err());
}
