//! Property tests for the content-addressed result store
//! (`apsp::store`): fingerprint stability/sensitivity, bit-exact
//! payload round trips, store invariants under random operation
//! sequences, and hit-served solutions bit-identical to fresh solves.
//!
//! All properties run on the seeded harness (`util::prop`); set
//! `RAPID_PROP_SEED` to explore fresh inputs, failures report a replay
//! seed.

use rapid_graph::apsp::admission::{AdmissionConfig, AdmissionGraph, StoreOutcome};
use rapid_graph::apsp::backend::NativeBackend;
use rapid_graph::apsp::dijkstra;
use rapid_graph::apsp::plan::{build_plan, ApspPlan, PlanOptions};
use rapid_graph::apsp::recursive::SolveOptions;
use rapid_graph::apsp::scheduler;
use rapid_graph::apsp::store::{
    fingerprint, CompressedMatrix, MemoryStore, ResultStore, StoreEntry,
};
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::prop::assert_prop;
use rapid_graph::util::rng::Rng;

fn plan_opts(tile: usize, seed: u64) -> PlanOptions {
    PlanOptions {
        tile_limit: tile,
        max_depth: usize::MAX,
        seed,
    }
}

/// A random connected-ish workload graph across topologies.
fn random_graph(r: &mut Rng) -> CsrGraph {
    let n = 20 + r.gen_range(100);
    let topo = match r.gen_range(3) {
        0 => Topology::Nws,
        1 => Topology::Er,
        _ => Topology::Grid,
    };
    let degree = 3.0 + r.gen_f64() * 5.0;
    generators::generate(topo, n, degree, Weights::Uniform(0.5, 8.0), r.next_u64())
}

// -----------------------------------------------------------------
// Fingerprinting
// -----------------------------------------------------------------

#[test]
fn fingerprint_invariant_under_clone_and_edge_order_permutation() {
    assert_prop(
        30,
        |r| {
            let g = random_graph(r);
            let shuffle_seed = r.next_u64();
            (g, shuffle_seed)
        },
        |(g, shuffle_seed)| {
            let h = fingerprint(g);
            if fingerprint(&g.clone()) != h {
                return Err("clone changed the fingerprint".into());
            }
            // rebuild from a randomly permuted edge list: `from_edges`
            // canonicalizes, so the fingerprint must not move
            let mut edges: Vec<(u32, u32, f32)> = g.edges().collect();
            let mut r = Rng::new(*shuffle_seed);
            r.shuffle(&mut edges);
            let g2 = CsrGraph::from_edges(g.n(), &edges);
            if fingerprint(&g2) != h {
                return Err(format!(
                    "edge-order permutation changed the fingerprint ({} edges)",
                    edges.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn fingerprint_sensitive_to_any_single_edge_edit() {
    assert_prop(
        30,
        |r| {
            let g = random_graph(r);
            let pick = r.next_u64();
            (g, pick)
        },
        |(g, pick)| {
            let h = fingerprint(g);
            let m = g.m();
            if m == 0 {
                return Err("generator produced an edgeless graph".into());
            }
            let mut r = Rng::new(*pick);
            // (1) reweight one directed edge in place (CSR is already
            // canonical, so this is a pure weight-bits change)
            let mut g_rw = g.clone();
            let k = r.gen_range(m);
            g_rw.val[k] += 0.5;
            if fingerprint(&g_rw) == h {
                return Err(format!("reweight of edge {k} kept the fingerprint"));
            }
            // (2) delete one directed edge
            let edges: Vec<(u32, u32, f32)> = g.edges().collect();
            let del = r.gen_range(edges.len());
            let mut fewer = edges.clone();
            fewer.remove(del);
            let g_del = CsrGraph::from_edges(g.n(), &fewer);
            if fingerprint(&g_del) == h {
                return Err(format!("delete of edge {del} kept the fingerprint"));
            }
            // (3) insert one absent edge (skip if the graph is complete)
            let mut absent = None;
            'outer: for u in 0..g.n() {
                for v in 0..g.n() {
                    if u != v && g.edge_weight(u, v).is_none() {
                        absent = Some((u as u32, v as u32));
                        break 'outer;
                    }
                }
            }
            if let Some((u, v)) = absent {
                let mut more = edges;
                more.push((u, v, 1.0));
                let g_ins = CsrGraph::from_edges(g.n(), &more);
                if fingerprint(&g_ins) == h {
                    return Err(format!("insert of ({u},{v}) kept the fingerprint"));
                }
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Compressed payload round trip
// -----------------------------------------------------------------

#[test]
fn compress_roundtrip_bit_exact_including_disconnected_inf() {
    assert_prop(
        25,
        |r| {
            // a graph with guaranteed isolated vertices, so the solved
            // distance matrix carries INF (unreachable) entries
            let n = 12 + r.gen_range(40);
            let live = n - 4;
            let mut edges: Vec<(u32, u32, f32)> = Vec::new();
            for _ in 0..(2 * n) {
                let u = r.gen_range(live) as u32;
                let v = r.gen_range(live) as u32;
                if u != v {
                    edges.push((u, v, r.gen_f32_range(0.5, 4.0)));
                }
            }
            CsrGraph::from_undirected_edges(n, &edges)
        },
        |g| {
            let d = dijkstra::apsp(g);
            let c = CompressedMatrix::compress(&d);
            let back = c.decompress();
            if back.n() != d.n() {
                return Err("dimension lost in round trip".into());
            }
            // bit-exact, not approximately equal
            for (i, (a, b)) in d.as_slice().iter().zip(back.as_slice()).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "entry {i} not bit-exact: {a} ({:#x}) vs {b} ({:#x})",
                        a.to_bits(),
                        b.to_bits()
                    ));
                }
            }
            let finite = d.finite_count();
            if finite == d.n() * d.n() {
                return Err("workload must contain INF entries".into());
            }
            if c.nnz() != finite {
                return Err(format!("nnz {} != finite count {finite}", c.nnz()));
            }
            if c.payload_bytes() != finite as u64 * 8 {
                return Err("payload bytes must be 8 per finite entry".into());
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Store invariants under random operation sequences
// -----------------------------------------------------------------

/// One randomized store-op script: (key, bytes, cost) puts with
/// interleaved gets, replayed against the capacity/budget invariants.
struct StoreScript {
    capacity: usize,
    budget: u64,
    ops: Vec<(u64, u64, f64)>,
}

#[test]
fn store_respects_capacity_budget_and_rejection_invariants() {
    assert_prop(
        60,
        |r| StoreScript {
            capacity: r.gen_range(4),
            budget: 50 + r.gen_range(250) as u64,
            ops: (0..24)
                .map(|_| {
                    (
                        r.gen_range(8) as u64,
                        1 + r.gen_range(320) as u64,
                        r.gen_f64() * 100.0,
                    )
                })
                .collect(),
        },
        |s| {
            let mut store = MemoryStore::new(s.capacity, s.budget);
            for &(key, bytes, cost) in &s.ops {
                let before = (store.len(), store.bytes_used(), store.keys());
                let res = store.put(key, StoreEntry::new(bytes, cost, None));
                if bytes > s.budget {
                    // oversized: clean error, nothing evicted
                    if res.is_ok() {
                        return Err(format!("oversized put ({bytes} > {}) accepted", s.budget));
                    }
                    if (store.len(), store.bytes_used(), store.keys()) != before {
                        return Err("oversized put mutated the store".into());
                    }
                    continue;
                }
                let stored = res.map_err(|e| format!("in-budget put failed: {e}"))?;
                if s.capacity == 0 {
                    if stored || !store.is_empty() {
                        return Err("capacity 0 must stay disabled and empty".into());
                    }
                    continue;
                }
                if !stored || !store.contains(key) {
                    return Err(format!("in-budget put of key {key} not stored"));
                }
                if store.get(key).is_none() {
                    return Err("get after put missed".into());
                }
                if store.len() > s.capacity {
                    return Err(format!(
                        "len {} exceeds capacity {}",
                        store.len(),
                        s.capacity
                    ));
                }
                if store.bytes_used() > s.budget {
                    return Err(format!(
                        "bytes_used {} exceeds budget {}",
                        store.bytes_used(),
                        s.budget
                    ));
                }
            }
            // determinism: replaying the same script reproduces the
            // same resident set (eviction has no hidden state)
            let mut replay = MemoryStore::new(s.capacity, s.budget);
            for &(key, bytes, cost) in &s.ops {
                let _ = replay.put(key, StoreEntry::new(bytes, cost, None));
                if bytes <= s.budget && s.capacity > 0 {
                    let _ = replay.get(key);
                }
            }
            // (the first pass also did a get after each successful put,
            // so the LRU clocks advance identically)
            if replay.keys() != store.keys() {
                return Err(format!(
                    "replay diverged: {:?} vs {:?}",
                    replay.keys(),
                    store.keys()
                ));
            }
            Ok(())
        },
    );
}

// -----------------------------------------------------------------
// Hit-served solutions: bit-identical to fresh solves
// -----------------------------------------------------------------

fn solve_workload(r: &mut Rng) -> (CsrGraph, u64) {
    let n = 60 + r.gen_range(80);
    let seed = r.next_u64();
    let g = generators::generate(Topology::Nws, n, 6.0, Weights::Uniform(1.0, 5.0), seed);
    (g, seed)
}

#[test]
fn run_local_hit_served_bit_identical_to_fresh_solve() {
    assert_prop(
        5,
        |r| solve_workload(r),
        |(g, seed)| {
            let plan = build_plan(g, plan_opts(32, *seed));
            let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(g, &plan), (g, &plan)];
            let arrivals = [0.0, 1e-4];
            let mut store = MemoryStore::new(8, 1 << 32);
            let (adm, outcomes) = AdmissionGraph::build_with_store(
                &subs,
                &arrivals,
                &AdmissionConfig::default(),
                &mut store,
                true,
            );
            match &outcomes[1] {
                Some(o) if o.is_hit() => {}
                o => return Err(format!("duplicate must hit, got {o:?}")),
            }
            let be = NativeBackend;
            let sols = scheduler::execute_admission_stored(&subs, &adm, &outcomes, &be, |_| {});
            let served = sols[1].as_ref().ok_or("hit must yield a solution")?;
            let fresh = scheduler::solve_dag(g, &plan, &be, SolveOptions::default());
            let diff = served.materialize_full(&be).max_diff(&fresh.materialize_full(&be));
            if diff != 0.0 {
                return Err(format!("hit-served solution differs by {diff}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prewarmed_hit_roundtrips_through_compressed_payload() {
    assert_prop(
        4,
        |r| solve_workload(r),
        |(g, seed)| {
            let plan = build_plan(g, plan_opts(32, *seed));
            let be = NativeBackend;
            let fresh = scheduler::solve_dag(g, &plan, &be, SolveOptions::default());
            let full = fresh.materialize_full(&be);
            // warm the store with the compressed solved result, as a
            // persistent deployment would across runs
            let cm = CompressedMatrix::compress(&full);
            let mut store = MemoryStore::new(8, 1 << 32);
            store
                .put(
                    fingerprint(g),
                    StoreEntry::new(cm.payload_bytes(), 1.0, Some(cm)),
                )
                .map_err(|e| format!("warm put failed: {e}"))?;
            let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(g, &plan)];
            let (adm, outcomes) = AdmissionGraph::build_with_store(
                &subs,
                &[0.0],
                &AdmissionConfig::default(),
                &mut store,
                true,
            );
            match &outcomes[0] {
                Some(StoreOutcome::Hit {
                    source: None,
                    payload: Some(_),
                }) => {}
                o => return Err(format!("pre-warmed submission must hit, got {o:?}")),
            }
            let sols = scheduler::execute_admission_stored(&subs, &adm, &outcomes, &be, |_| {});
            let served = sols[0].as_ref().ok_or("hit must yield a solution")?;
            let diff = served.materialize_full(&be).max_diff(&full);
            if diff != 0.0 {
                return Err(format!("payload-served solution differs by {diff}"));
            }
            Ok(())
        },
    );
}
