//! Fig. 7 reproduction: RAPID-Graph vs CPU / A100 / H100 at n = 100,
//! 1024, 32768 (speedup and energy efficiency).
//!
//! The CPU column is *measured on this host* (the crate's own parallel
//! FW kernel, then scaled cubically); GPU columns are the calibrated
//! roofline models; RAPID-Graph comes from the cycle-level simulator
//! driven by the real recursion trace.
//!
//!     cargo bench --bench fig7_speedup

use rapid_graph::baselines::cpu::CpuModel;
use rapid_graph::bench::figures;
use rapid_graph::coordinator::config::SystemConfig;

fn main() {
    println!("=== Fig. 7: RAPID-Graph vs CPU / A100 / H100 ===");
    println!("paper reference points: 1061x/7208x vs CPU at n=1024;");
    println!("                        42.8x/392x vs H100 at n=32768\n");
    let cfg = SystemConfig::default();

    // --- CPU column = the paper's part (i7-11700K class constant)
    println!("--- CPU column: i7-11700K model (the paper's baseline part) ---");
    let (speed, energy) = figures::fig7(&cfg, &CpuModel::paper(), &[100, 1024, 32768]);
    speed.print();
    energy.print();

    // --- CPU column = this host, measured with our own optimized kernel
    let cpu = CpuModel::calibrated();
    println!(
        "--- CPU column: THIS HOST, measured (n={} took {:.3}s with the \
         crate's vectorized FW — a far stronger baseline than naive FW) ---",
        cpu.measured_at.0, cpu.measured_at.1
    );
    let (speed, energy) = figures::fig7(&cfg, &cpu, &[100, 1024, 32768]);
    speed.print();
    energy.print();
}
