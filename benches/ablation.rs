//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * recursion (Algorithm 2) vs single-level (Algorithm 1)
//! * PCM-FW permutation unit on/off (paper §III-C motivation)
//! * PCM-MP comparator tree vs serial reduction (Fig. 5e)
//! * HBM3 load/compute prefetch on/off (dataflow step 3ii)
//! * tile-limit sweep (why 1024, §III-A)
//!
//!     cargo bench --bench ablation

use rapid_graph::coordinator::config::{Mode, SystemConfig};
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::table::{fmt_energy, fmt_ratio, fmt_time, Table};

fn run(cfg: &SystemConfig, g: &rapid_graph::CsrGraph) -> (f64, f64, usize) {
    let ex = Executor::new(cfg.clone()).unwrap();
    let r = ex.run(g).unwrap();
    (r.sim.seconds, r.sim.joules, r.final_n)
}

fn main() {
    let n = 65_536;
    let g = generators::generate(
        Topology::OgbnProxy,
        n,
        25.25,
        Weights::Uniform(1.0, 8.0),
        7,
    );
    println!(
        "workload: OGBN-proxy n={} m={} (estimate mode; trace identical to functional)\n",
        g.n(),
        g.m()
    );
    let mut base_cfg = SystemConfig::default();
    base_cfg.mode = Mode::Estimate;
    let (base_s, base_j, _) = run(&base_cfg, &g);

    let mut t = Table::new(
        "ablations (vs full RAPID-Graph config)",
        &["config", "time", "energy", "slowdown", "energy cost"],
    );
    t.row(&[
        "full system".into(),
        fmt_time(base_s),
        fmt_energy(base_j),
        "1x".into(),
        "1x".into(),
    ]);

    // recursion off (Algorithm 1): giant terminal boundary solve
    let mut cfg = base_cfg.clone();
    cfg.max_depth = 1;
    let (s, j, final_n) = run(&cfg, &g);
    t.row(&[
        format!("no recursion (Alg 1, final dense n={final_n})"),
        fmt_time(s),
        fmt_energy(j),
        fmt_ratio(s / base_s),
        fmt_ratio(j / base_j),
    ]);

    // permutation unit off
    let mut cfg = base_cfg.clone();
    cfg.hw.permutation_unit = false;
    let (s, j, _) = run(&cfg, &g);
    t.row(&[
        "no permutation unit (row-by-row DMA)".into(),
        fmt_time(s),
        fmt_energy(j),
        fmt_ratio(s / base_s),
        fmt_ratio(j / base_j),
    ]);

    // comparator tree off
    let mut cfg = base_cfg.clone();
    cfg.hw.comparator_tree = false;
    let (s, j, _) = run(&cfg, &g);
    t.row(&[
        "no comparator tree (serial min)".into(),
        fmt_time(s),
        fmt_energy(j),
        fmt_ratio(s / base_s),
        fmt_ratio(j / base_j),
    ]);

    // prefetch off
    let mut cfg = base_cfg.clone();
    cfg.hw.prefetch = false;
    let (s, j, _) = run(&cfg, &g);
    t.row(&[
        "no HBM prefetch (loads serialize)".into(),
        fmt_time(s),
        fmt_energy(j),
        fmt_ratio(s / base_s),
        fmt_ratio(j / base_j),
    ]);
    t.print();

    // tile-limit sweep (paper §III-A: why 1024)
    let mut t = Table::new(
        "tile-limit sweep (paper fixes 1024 = PCM array dimension)",
        &["tile limit", "time", "energy", "depth", "final_n"],
    );
    for tile in [256usize, 512, 1024] {
        let mut cfg = base_cfg.clone();
        cfg.tile_limit = tile;
        let ex = Executor::new(cfg).unwrap();
        let r = ex.run(&g).unwrap();
        t.row(&[
            tile.to_string(),
            fmt_time(r.sim.seconds),
            fmt_energy(r.sim.joules),
            r.depth.to_string(),
            r.final_n.to_string(),
        ]);
    }
    t.print();
}
