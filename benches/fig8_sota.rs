//! Fig. 8 reproduction: RAPID-Graph vs PIM-APSP [16], Partitioned APSP
//! [10] and Co-Parallel APSP [11] on OGBN-Products (2.449M vertices,
//! avg degree 25.25).
//!
//! By default runs a 500k-vertex proxy (full plan + trace + simulation
//! in under a minute); pass `--full` for the complete 2.449M-vertex
//! workload (several minutes, multilevel-partitions a 62M-edge graph).
//!
//!     cargo bench --bench fig8_sota [-- --full]

use rapid_graph::bench::figures;
use rapid_graph::bench::workload::OGBN_N;
use rapid_graph::coordinator::config::SystemConfig;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let n = if full { OGBN_N } else { 500_000 };
    println!("=== Fig. 8: SOTA comparison on OGBN-Products ===");
    println!("paper reference points (at 2.449M): 5.8x speedup over");
    println!("Co-Parallel APSP, 1186x energy savings over Partitioned");
    println!("APSP; PIM-APSP at 0.7x speed / 11.4x energy of baseline\n");
    if !full {
        println!("(proxy at n={n}; pass `--full` for the 2.449M run)\n");
    }
    let t0 = std::time::Instant::now();
    figures::fig8(&SystemConfig::default(), n).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
