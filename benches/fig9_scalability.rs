//! Fig. 9 reproduction: scalability of RAPID-Graph vs the H100 baseline
//! across (a,d) degree, (b,e) size, and (c,f) topology.
//!
//!     cargo bench --bench fig9_scalability [-- --part degree|size|topology] [-- --full]

use rapid_graph::bench::figures;
use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::graph::generators::Topology;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let part = args
        .iter()
        .position(|a| a == "--part")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("all");
    let cfg = SystemConfig::default();

    if part == "all" || part == "degree" {
        println!("=== Fig. 9(a,d): degree sweep at fixed size ===");
        println!("paper: flat performance across a 4x degree sweep\n");
        figures::fig9_degree(&cfg, 32_768, &[12.5, 25.25, 50.0, 100.0]).print();
    }
    if part == "all" || part == "size" {
        println!("=== Fig. 9(b,e): size sweep at degree 25.25 ===");
        println!("paper: RAPID scales linearly to 2.45M nodes; H100 rises");
        println!("superlinearly beyond ~10^3 nodes\n");
        let sizes: Vec<usize> = if full {
            vec![1024, 8192, 65_536, 262_144, 1_048_576, 2_449_029]
        } else {
            vec![1024, 8192, 65_536, 262_144]
        };
        let (t, series) = figures::fig9_size(&cfg, &sizes);
        t.print();
        println!("seconds/vertex (flat = linear):");
        for (n, s) in series {
            println!("  n={n:>9}: {:.3e}", s / n as f64);
        }
        println!();
    }
    if part == "all" || part == "topology" {
        println!("=== Fig. 9(c,f): topology sweep ===");
        println!("paper: clustered (NWS) and real (OGBN) beat random (ER);");
        println!("H100 is topology-insensitive\n");
        let n = if full { 131_072 } else { 32_768 };
        figures::fig9_topology(
            &cfg,
            n,
            &[Topology::Nws, Topology::OgbnProxy, Topology::Er],
        )
        .0
        .print();
    }
}
