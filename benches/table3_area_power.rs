//! Table III reproduction: area and power breakdown per PCM unit (FW
//! and MP flavors), plus the §IV-B system-level components and derived
//! die totals.
//!
//!     cargo bench --bench table3_area_power

use rapid_graph::bench::figures;
use rapid_graph::sim::area;
use rapid_graph::sim::params::HwParams;

fn main() {
    println!("=== Table II: PCM cell parameters (Sb2Te3/Ge4Sb6Te7 SLC) ===");
    let p = HwParams::default();
    println!("  reset/set time        : {} ns / {} ns", p.pcm_write_ns, p.pcm_write_ns);
    println!("  programming energy    : {} pJ", p.pcm_program_pj);
    println!("  clock cycle           : {} ns ({} MHz)", 1e9 / p.clock_hz, p.clock_hz / 1e6);
    println!("  unit dimension        : {0} x {0}", p.unit_dim);
    println!("  units per tile        : {}", p.units_per_tile);
    println!("  tiles per die         : {}\n", p.tiles_per_die);

    println!("=== Table III: area/power per PCM unit ===\n");
    for t in figures::table3() {
        t.print();
    }

    println!("derived die-level totals:");
    for unit in [area::pcm_fw_unit(), area::pcm_mp_unit()] {
        println!(
            "  {} die: {:.0} mm^2 across {} tiles x {} units",
            unit.die,
            area::die_area_mm2(&p, &unit),
            p.tiles_per_die,
            p.units_per_tile
        );
    }
}
