//! Kernel micro-benchmarks: native rust vs PJRT (AOT JAX/Pallas) tile
//! engines for FW blocks and min-plus merges, across size classes.
//!
//! This quantifies the L3 hot path (the functional backend) and the
//! PJRT dispatch overhead — see EXPERIMENTS.md §Perf.
//!
//!     make artifacts && cargo bench --bench kernels

use rapid_graph::apsp::backend::{NativeBackend, TileBackend};
use rapid_graph::apsp::floyd_warshall;
use rapid_graph::graph::generators::{self, Weights};
use rapid_graph::runtime::PjrtRuntime;
use rapid_graph::util::bench::{bench, BenchOpts};
use rapid_graph::util::rng::Rng;
use rapid_graph::util::table::{fmt_time, Table};

fn main() {
    let runtime = PjrtRuntime::load_default().ok();
    if runtime.is_none() {
        println!("note: artifacts missing, PJRT columns skipped (run `make artifacts`)\n");
    }

    // ---- FW blocks
    let mut t = Table::new(
        "FW block kernels (one full pass, per call)",
        &["n", "native serial", "native parallel", "pjrt", "native Gmadd/s"],
    );
    for &n in &[128usize, 256, 512, 1024] {
        let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 5.0), n as u64);
        let base = g.to_dense();
        let opts = if n >= 512 { BenchOpts::quick() } else { BenchOpts::default() };

        let m_serial = bench(opts, || {
            let mut d = base.clone();
            floyd_warshall::fw_rowwise(&mut d);
            std::hint::black_box(d.get(0, 1));
        });
        let m_par = bench(opts, || {
            let mut d = base.clone();
            floyd_warshall::fw_parallel(&mut d);
            std::hint::black_box(d.get(0, 1));
        });
        let pjrt_cell = if let Some(rt) = &runtime {
            let m = bench(opts, || {
                let mut d = base.clone();
                rt.fw_block(&mut d).unwrap();
                std::hint::black_box(d.get(0, 1));
            });
            fmt_time(m.mean_secs())
        } else {
            "-".to_string()
        };
        let gmadds = (n as f64).powi(3) / m_par.mean_secs() / 1e9;
        t.row(&[
            n.to_string(),
            fmt_time(m_serial.mean_secs()),
            fmt_time(m_par.mean_secs()),
            pjrt_cell,
            format!("{gmadds:.2}"),
        ]);
    }
    t.print();

    // ---- min-plus merges
    let mut t = Table::new(
        "min-plus merge kernels (C = min(C, A (+) B), per call)",
        &["m=k=n", "native serial", "native parallel", "pjrt"],
    );
    let be = NativeBackend;
    for &n in &[128usize, 256, 512, 1024] {
        let mut rng = Rng::new(n as u64);
        let gen = |rng: &mut Rng| -> Vec<f32> {
            (0..n * n)
                .map(|_| {
                    if rng.gen_bool(0.2) {
                        f32::INFINITY
                    } else {
                        rng.gen_f32_range(0.0, 9.0)
                    }
                })
                .collect()
        };
        let a = gen(&mut rng);
        let b = gen(&mut rng);
        let c0 = vec![f32::INFINITY; n * n];
        let opts = if n >= 512 { BenchOpts::quick() } else { BenchOpts::default() };
        let m_serial = bench(opts, || {
            let mut c = c0.clone();
            rapid_graph::apsp::minplus::minplus_into(&mut c, &a, &b, n, n, n);
            std::hint::black_box(c[0]);
        });
        let m_par = bench(opts, || {
            let mut c = c0.clone();
            be.minplus_into(&mut c, &a, &b, n, n, n);
            std::hint::black_box(c[0]);
        });
        let pjrt_cell = if let Some(rt) = &runtime {
            let m = bench(opts, || {
                let mut c = c0.clone();
                rt.minplus_into(&mut c, &a, &b, n, n, n).unwrap();
                std::hint::black_box(c[0]);
            });
            fmt_time(m.mean_secs())
        } else {
            "-".to_string()
        };
        t.row(&[
            n.to_string(),
            fmt_time(m_serial.mean_secs()),
            fmt_time(m_par.mean_secs()),
            pjrt_cell,
        ]);
    }
    t.print();
}
