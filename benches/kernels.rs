//! Kernel micro-benchmarks: native rust vs PJRT (AOT JAX/Pallas) tile
//! engines for FW blocks and min-plus merges, across size classes —
//! plus the scheduler benchmark (barrier walk vs tile-task DAG) on a
//! multi-component graph, for both the host executor's wall clock and
//! the simulator's modeled makespan — and the admission benchmark
//! (async admission vs drain-and-rebatch on staggered arrivals), which
//! `--admission-only --json BENCH_admission.json` reduces to the CI
//! perf-snapshot artifact. The delta benchmark (`--delta-only --json
//! BENCH_delta.json`) sweeps edge-delta batch sizes through the
//! incremental repair engine and records repair makespan vs the full
//! re-solve baseline. The serve benchmark (`--serve-only --json
//! BENCH_serve.json`) drains mixed query batches against a published
//! next-hop snapshot and records QPS, drain-latency percentiles,
//! snapshot-swap stalls under concurrent delta repair, and batched
//! path reconstruction vs per-query Dijkstra. The semiring benchmark
//! (`--semiring-only --json BENCH_semiring.json`) times the generic
//! row-wise FW pass for each shipped semiring and asserts bit-identity
//! against a naive ⊕/⊗ scalar oracle. Every JSON artifact is assembled
//! through the shared `util::bench::BenchDoc` builder (schema name,
//! floors/ceilings, drift bands), so the emitters cannot drift apart
//! on shape.
//!
//! This quantifies the L3 hot path (the functional backend) and the
//! PJRT dispatch overhead — see EXPERIMENTS.md §Perf.
//!
//!     make artifacts && cargo bench --bench kernels

use rapid_graph::apsp::admission::{AdmissionConfig, AdmissionGraph};
use rapid_graph::apsp::backend::{NativeBackend, TileBackend};
use rapid_graph::apsp::batch::BatchGraph;
use rapid_graph::apsp::delta::{self, DeltaClass, EdgeDelta};
use rapid_graph::apsp::plan::{build_plan, ApspPlan, PlanOptions};
use rapid_graph::apsp::store::MemoryStore;
use rapid_graph::apsp::recursive::{solve, SolveOptions};
use rapid_graph::apsp::shard::ShardGraph;
use rapid_graph::apsp::taskgraph::TaskGraph;
use rapid_graph::apsp::{floyd_warshall, scheduler, taskgraph};
use rapid_graph::graph::csr::CsrGraph;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::runtime::PjrtRuntime;
use rapid_graph::sim::{engine, HwParams};
use rapid_graph::util::bench::{bench, BenchDoc, BenchOpts};
use rapid_graph::util::rng::Rng;
use rapid_graph::util::table::{fmt_ratio, fmt_time, Table};
use rapid_graph::util::threads;

/// Counting global allocator (`--features count_alloc`): every heap
/// allocation increments a counter, so `--host-perf` can *assert* the
/// warmed kernel hot path is allocation-free rather than eyeball it.
#[cfg(feature = "count_alloc")]
mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub struct Counting;

    // SAFETY: defers every operation to `System`; only adds a counter.
    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, l: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(l)
        }
        unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
            System.dealloc(p, l)
        }
        unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(p, l, new_size)
        }
    }

    #[global_allocator]
    static COUNTER: Counting = Counting;

    pub fn allocs() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }
}

/// Multi-component scheduler workload: 8 bridged communities (shared
/// boundary hierarchy) plus one large isolated clique. The barrier walk
/// serializes the clique's FW against the whole boundary recursion; the
/// DAG executor overlaps them (the clique has no boundary, so nothing
/// downstream waits on it).
fn scheduler_workload() -> CsrGraph {
    let mut rng = Rng::new(0xDA6);
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    // communities of 600: two would overflow a 1024-tile, so each one
    // is its own component — 8 components plus the isolated clique
    let commns = 8u32;
    let csize = 600u32;
    for c in 0..commns {
        let base = c * csize;
        // dense-ish community: ~20% of pairs
        for i in 0..csize {
            for j in (i + 1)..csize {
                if rng.gen_bool(0.2) {
                    edges.push((base + i, base + j, rng.gen_f32_range(1.0, 5.0)));
                }
            }
        }
        // a few cross links: small boundary, real boundary hierarchy
        if c > 0 {
            for _ in 0..8 {
                let u = (c - 1) * csize + rng.gen_range(csize as usize) as u32;
                let v = base + rng.gen_range(csize as usize) as u32;
                edges.push((u, v, rng.gen_f32_range(2.0, 8.0)));
            }
        }
    }
    // isolated clique: heavy FW, zero boundary — the barrier walk
    // stalls the whole boundary recursion on it, the DAG overlaps it
    let gbase = commns * csize;
    let gsize = 800u32;
    for i in 0..gsize {
        for j in (i + 1)..gsize {
            edges.push((gbase + i, gbase + j, rng.gen_f32_range(1.0, 3.0)));
        }
    }
    CsrGraph::from_undirected_edges((gbase + gsize) as usize, &edges)
}

fn bench_schedulers() {
    let g = scheduler_workload();
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 1024,
            max_depth: usize::MAX,
            seed: 0xDA6,
        },
    );
    let be = NativeBackend;
    let k0 = plan.levels.first().map(|l| l.n_components()).unwrap_or(1);
    println!(
        "scheduler workload: n={} m={} components={} depth={} boundary={:?}\n",
        g.n(),
        g.m(),
        k0,
        plan.depth(),
        plan.boundary_sizes()
    );
    let opts = BenchOpts::quick();
    let m_barrier = bench(opts, || {
        let s = solve(&g, &plan, Some(&be), SolveOptions::default());
        std::hint::black_box(s.query(0, 1));
    });
    let m_dag = bench(opts, || {
        let s = scheduler::solve_dag(&g, &plan, &be, SolveOptions::default());
        std::hint::black_box(s.query(0, 1));
    });
    let mut t = Table::new(
        "host executor: barrier walk vs tile-task DAG (functional solve)",
        &["scheduler", "wall time", "speedup"],
    );
    t.row(&["barrier".into(), fmt_time(m_barrier.mean_secs()), "1x".into()]);
    t.row(&[
        "dag".into(),
        fmt_time(m_dag.mean_secs()),
        fmt_ratio(m_barrier.mean_secs() / m_dag.mean_secs()),
    ]);
    t.print();

    // modeled hardware makespan under the two sim schedulers
    let tg = taskgraph::lower(&plan);
    let hw = HwParams::default();
    let sim_barrier = engine::simulate(&tg.to_trace(), &hw);
    let sim_dag = engine::simulate_dag(&tg, &hw);
    let mut t = Table::new(
        "simulator: step-barrier vs dependency-aware makespan",
        &["schedule", "modeled time", "speedup"],
    );
    t.row(&["barrier".into(), fmt_time(sim_barrier.seconds), "1x".into()]);
    t.row(&[
        "dag".into(),
        fmt_time(sim_dag.seconds),
        fmt_ratio(sim_barrier.seconds / sim_dag.seconds),
    ]);
    t.print();
}

/// Batch-engine workload: 8 heterogeneous graphs (NWS / ER / grid /
/// OGBN-proxy mixes of varying size). Submitted one at a time, each
/// graph's critical-path bubbles leave the modeled dies idle; merged
/// into one shared-resource schedule, the independent task graphs fill
/// each other's bubbles — FW-die utilization climbs with batch size and
/// the batch makespan lands well under the serial sum.
fn bench_batching() {
    let specs: [(Topology, usize, f64, u64); 8] = [
        (Topology::Nws, 3_000, 12.0, 1),
        (Topology::Er, 2_000, 10.0, 2),
        (Topology::Grid, 2_500, 4.0, 3),
        (Topology::OgbnProxy, 4_000, 14.0, 4),
        (Topology::Nws, 1_500, 20.0, 5),
        (Topology::OgbnProxy, 2_500, 10.0, 6),
        (Topology::Er, 3_500, 8.0, 7),
        (Topology::Grid, 1_800, 4.0, 8),
    ];
    let hw = HwParams::default();
    let tgs: Vec<TaskGraph> = specs
        .iter()
        .map(|&(topo, n, degree, seed)| {
            let g = generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), seed);
            let plan = build_plan(
                &g,
                PlanOptions {
                    tile_limit: 1024,
                    max_depth: usize::MAX,
                    seed,
                },
            );
            taskgraph::lower(&plan)
        })
        .collect();
    let mut t = Table::new(
        "multi-graph batch engine: shared schedule vs serial submission (modeled)",
        &["batch", "serial sum", "batch makespan", "speedup", "FW util", "MP util"],
    );
    for &k in &[1usize, 2, 4, 8] {
        let subset: Vec<TaskGraph> = tgs[..k].to_vec();
        let serial: f64 = subset
            .iter()
            .map(|tg| engine::simulate_dag(tg, &hw).seconds)
            .sum();
        let batch = BatchGraph::merge(subset);
        let (rep, _) = engine::simulate_batch(&batch, &hw);
        t.row(&[
            k.to_string(),
            fmt_time(serial),
            fmt_time(rep.seconds),
            fmt_ratio(serial / rep.seconds),
            format!("{:.1}%", 100.0 * rep.fw_utilization()),
            format!("{:.1}%", 100.0 * rep.mp_utilization()),
        ]);
    }
    t.print();
}

/// Shard-scaling curve: modeled makespan and interconnect occupancy vs
/// stack count, on a boundary-light topology (OGBN-proxy communities:
/// tiny b per component, cross-shard traffic negligible, speedup tracks
/// the replicated channels/dies) and a boundary-heavy one (ER random:
/// fat boundary matrices serialize on the capacity-1 interconnect and
/// the hub's shared recursion, flattening the curve). This is where the
/// bench shows cross-shard traffic eating the scale-out gain.
fn bench_sharding() {
    let hw = HwParams::default();
    let cases: [(&str, Topology, usize, f64, u64); 2] = [
        ("boundary-light (OGBN-proxy)", Topology::OgbnProxy, 30_000, 14.0, 11),
        ("boundary-heavy (ER random)", Topology::Er, 12_000, 25.25, 12),
    ];
    for (label, topo, n, degree, seed) in cases {
        let g = generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), seed);
        let plan = build_plan(
            &g,
            PlanOptions {
                tile_limit: 1024,
                max_depth: usize::MAX,
                seed,
            },
        );
        let boundary: usize = plan.boundary_sizes().first().copied().unwrap_or(0);
        println!(
            "shard workload [{label}]: n={} m={} tiles={} boundary(L0)={}\n",
            g.n(),
            g.m(),
            rapid_graph::apsp::shard::plan_tiles(&plan),
            boundary
        );
        let mut t = Table::new(
            &format!("shard scaling: {label} (modeled)"),
            &["stacks", "makespan", "speedup", "interconnect busy", "xfer bytes"],
        );
        let mut base = 0.0f64;
        for &s in &[1usize, 2, 4, 8] {
            let shard = ShardGraph::build(&plan, s, seed);
            let (rep, _) = engine::simulate_sharded(&shard, &hw);
            if s == 1 {
                base = rep.seconds;
            }
            t.row(&[
                s.to_string(),
                fmt_time(rep.seconds),
                fmt_ratio(base / rep.seconds),
                fmt_time(rep.interconnect_busy),
                rapid_graph::util::table::fmt_count(shard.xfer_bytes as usize),
            ]);
        }
        t.print();
    }
}

/// Admission-pipeline workload: six heterogeneous graphs submitted on
/// a staggered modeled arrival schedule (15% of the first graph's solo
/// makespan between arrivals) through a depth-4 bounded admission
/// queue. Quick/estimate mode — pure lowering +
/// simulation, no host numerics — so CI can snapshot the modeled
/// makespans and the admission latency percentiles cheaply. With
/// `--json PATH` the numbers are also dumped as machine-readable JSON
/// (the CI perf-snapshot artifact `BENCH_admission.json`).
fn bench_admission(json_out: Option<&str>) {
    use rapid_graph::util::json;
    let specs: [(Topology, usize, f64, u64); 6] = [
        (Topology::Nws, 3_000, 12.0, 21),
        (Topology::Er, 2_000, 10.0, 22),
        (Topology::Grid, 2_500, 4.0, 23),
        (Topology::OgbnProxy, 4_000, 14.0, 24),
        (Topology::Nws, 1_500, 20.0, 25),
        (Topology::OgbnProxy, 2_500, 10.0, 26),
    ];
    let hw = HwParams::default();
    let tgs: Vec<TaskGraph> = specs
        .iter()
        .map(|&(topo, n, degree, seed)| {
            let g = generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), seed);
            let plan = build_plan(
                &g,
                PlanOptions {
                    tile_limit: 1024,
                    max_depth: usize::MAX,
                    seed,
                },
            );
            taskgraph::lower(&plan)
        })
        .collect();
    let first = engine::simulate_dag(&tgs[0], &hw).seconds;
    let arrivals: Vec<f64> = (0..tgs.len()).map(|i| i as f64 * 0.15 * first).collect();
    let queue_depth = 4;
    let batch = BatchGraph::merge(tgs);
    let (rep, stats) = engine::simulate_admission(&batch, &arrivals, queue_depth, &hw);
    let (drain, drain_completion) =
        engine::simulate_drain_rebatch(&batch.per_graph, &arrivals, &hw);

    let mut t = Table::new(
        "async admission vs drain-and-rebatch (modeled, staggered arrivals)",
        &["graph", "arrival", "finish", "latency", "drain latency"],
    );
    for (i, (st, &a)) in stats.iter().zip(&arrivals).enumerate() {
        t.row(&[
            i.to_string(),
            fmt_time(a),
            fmt_time(st.makespan),
            fmt_time(st.makespan - a),
            fmt_time(drain_completion[i] - a),
        ]);
    }
    t.print();
    println!(
        "admission makespan {} (queue depth {queue_depth}) vs drain-and-rebatch {} \
         -> {} throughput, FW util {:.1}%\n",
        fmt_time(rep.seconds),
        fmt_time(drain),
        fmt_ratio(drain / rep.seconds),
        100.0 * rep.fw_utilization(),
    );

    // ---- result store: a duplicate-heavy stream (the same graph
    // submitted three times through a depth-1 queue), where the
    // content-addressed store turns two of the three solves into
    // modeled FeNAND reads. queue depth 1 serializes the stream, so
    // the cache's makespan gain is isolated from schedule overlap.
    let (store_hits, store_makespan, store_plain) = store_metrics(&hw);
    let cache_speedup = store_plain / store_makespan;
    println!(
        "result store (duplicate-heavy stream, queue depth 1): {store_hits} hits / 3 \
         submissions, makespan {} vs no-store {} -> cache_speedup {}\n",
        fmt_time(store_makespan),
        fmt_time(store_plain),
        fmt_ratio(cache_speedup),
    );

    let lat: Vec<f64> = stats
        .iter()
        .zip(&arrivals)
        .map(|(st, &a)| st.makespan - a)
        .collect();
    let pct = |p: f64| rapid_graph::util::bench::percentile(&lat, p);
    if let Some(path) = json_out {
        let per_graph: Vec<json::Json> = stats
            .iter()
            .zip(&arrivals)
            .zip(&drain_completion)
            .map(|((st, &a), &dc)| {
                json::obj(vec![
                    ("arrival_s", json::num(a)),
                    ("finish_s", json::num(st.makespan)),
                    ("latency_s", json::num(st.makespan - a)),
                    ("drain_latency_s", json::num(dc - a)),
                ])
            })
            .collect();
        // host wall-clock keys ride along for trend inspection; CI never
        // drift-gates them (machine-dependent)
        let host = measure_host_perf(BenchOpts::quick());
        BenchDoc::new("admission_staggered_6")
            .count("graphs", batch.n_graphs())
            .count("queue_depth", queue_depth)
            .num("admission_makespan_s", rep.seconds)
            .num("drain_makespan_s", drain)
            .num("speedup_vs_drain", drain / rep.seconds)
            .num("latency_p50_s", pct(0.5))
            .num("latency_p90_s", pct(0.9))
            .num("latency_max_s", pct(1.0))
            .count("store_hits", store_hits)
            .num("store_makespan_s", store_makespan)
            .num("store_no_cache_makespan_s", store_plain)
            .num("cache_speedup", cache_speedup)
            .field("per_graph", json::arr(per_graph))
            .extend_fields(host.json_fields())
            .write(path)
            .expect("write bench json");
        println!("wrote {path}\n");
    }
}

/// Delta-engine benchmark: repair latency vs delta size on a
/// figure-style NWS workload. The base graph is solved once with
/// retained repair state; each sweep point samples a fraction of the
/// undirected edges, reweights them slightly downward (improve class,
/// so the repair engine can *prove* unchanged boundary blocks and skip
/// their rerun), executes the repair functionally to obtain the actual
/// post-skip closure, and prices that repair sub-DAG against the full
/// re-solve lowering. Repair makespan must grow with the dirty-tile
/// count — not with n³ — which is the whole point of the engine. With
/// `--json PATH` the sweep lands in the CI perf-snapshot artifact
/// `BENCH_delta.json`.
fn bench_delta(json_out: Option<&str>) {
    use rapid_graph::util::json;
    let seed = 0xDE17A_u64;
    let g = generators::generate(Topology::Nws, 4_096, 12.0, Weights::Uniform(1.0, 5.0), seed);
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 256,
            max_depth: usize::MAX,
            seed,
        },
    );
    let hw = HwParams::default();
    let be = NativeBackend;
    let full_tg = taskgraph::lower(&plan);
    let total_tiles = plan.levels.first().map(|l| l.n_components()).unwrap_or(1);
    let (_, state) = scheduler::solve_dag_retained(&g, &plan, &be, SolveOptions::default());
    let resolve_s = engine::simulate_dag(&full_tg, &hw).seconds;
    println!(
        "delta workload: n={} m={} tiles={} depth={} re-solve makespan {}\n",
        g.n(),
        g.m(),
        total_tiles,
        plan.depth(),
        fmt_time(resolve_s),
    );

    // undirected edge list (u < v) to sample delta batches from
    let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).collect();
    let mut rng = Rng::new(seed);
    let mut t = Table::new(
        "incremental repair vs full re-solve (modeled makespan)",
        &["delta", "edges", "dirty tiles", "skipped", "repair", "delta_speedup"],
    );
    let mut sweep: Vec<rapid_graph::util::json::Json> = Vec::new();
    let mut speedup_1pct = 0.0f64;
    for &frac in &[0.001f64, 0.005, 0.01, 0.05] {
        let k = ((edges.len() as f64 * frac).ceil() as usize).max(1);
        // sample k distinct edges: partial Fisher-Yates over indices
        let mut idx: Vec<usize> = (0..edges.len()).collect();
        for i in 0..k {
            let j = i + rng.gen_range(idx.len() - i);
            idx.swap(i, j);
        }
        let batch: Vec<EdgeDelta> = idx[..k]
            .iter()
            .map(|&e| {
                let (u, v, w) = edges[e];
                EdgeDelta::Reweight { u, v, w: w * 0.99 }
            })
            .collect();
        delta::validate_deltas(&g, &batch).expect("sampled deltas are valid");
        let class = delta::classify_deltas(&g, &batch);
        let g2 = delta::apply_deltas(&g, &batch);
        let plan2 = delta::repair_plan(&plan, &g2).expect("reweights never change structure");
        let spec = delta::dirty_spec(&plan2, &batch);
        let (_, actual) = scheduler::execute_delta(
            &g2,
            &plan2,
            &spec,
            &state,
            class == DeltaClass::Improve,
            &be,
            SolveOptions::default(),
        );
        let repair_tg = taskgraph::lower_repair(&plan2, &actual);
        let (repair_rep, resolve_rep) = engine::simulate_delta(&repair_tg, &full_tg, &hw);
        let dirty = actual.dirty_tiles().max(1);
        let skipped = spec.rerun.iter().filter(|r| **r).count()
            - actual.rerun.iter().filter(|r| **r).count();
        let speedup = resolve_rep.seconds / repair_rep.seconds;
        if frac == 0.01 {
            speedup_1pct = speedup;
        }
        t.row(&[
            format!("{:.1}%", 100.0 * frac),
            k.to_string(),
            format!("{dirty}/{total_tiles}"),
            skipped.to_string(),
            fmt_time(repair_rep.seconds),
            fmt_ratio(speedup),
        ]);
        sweep.push(json::obj(vec![
            ("delta_frac", json::num(frac)),
            ("n_deltas", json::num(k as f64)),
            ("dirty_tiles", json::num(dirty as f64)),
            ("skipped_tiles", json::num(skipped as f64)),
            ("repair_makespan_s", json::num(repair_rep.seconds)),
            ("delta_speedup", json::num(speedup)),
        ]));
    }
    t.print();
    println!(
        "delta_speedup at 1% of edges: {}\n",
        fmt_ratio(speedup_1pct)
    );

    if let Some(path) = json_out {
        BenchDoc::new("delta_sweep_nws4096")
            .count("graph_n", g.n())
            .count("graph_m", g.m())
            .count("total_tiles", total_tiles)
            .num("resolve_makespan_s", resolve_s)
            .num("delta_speedup_1pct", speedup_1pct)
            .field("sweep", json::arr(sweep))
            .write(path)
            .expect("write delta bench json");
        println!("wrote {path}\n");
    }
}

/// Serve-loop benchmark: drain mixed query batches (dist/path/knear/
/// reach) against a published next-hop snapshot on a figure-style NWS
/// workload. Reports measured QPS and drain-latency percentiles, the
/// snapshot-swap stall/torn counters under concurrent delta repair
/// (reader threads hammer the lock-free cell while the writer re-solves
/// and epoch-swaps), and batched path reconstruction vs per-query
/// Dijkstra — the ISSUE's ≥10× acceptance metric. With `--json PATH`
/// the numbers land in the CI serve-snapshot artifact
/// `BENCH_serve.json`; CI validates the fresh artifact against the
/// committed thresholds (floors/ceilings, not drift bands — wall-clock
/// QPS is machine-dependent).
fn bench_serve(json_out: Option<&str>) {
    use rapid_graph::apsp::dijkstra;
    use rapid_graph::apsp::query::{self, Query, QueryReq};
    use rapid_graph::apsp::serve::{BatchExec, QuerySnapshot, SnapshotCell};
    use rapid_graph::util::bench::percentile;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let seed = 0x5E12E_u64;
    let g = generators::generate(Topology::Nws, 1_024, 12.0, Weights::Uniform(1.0, 5.0), seed);
    let n = g.n();
    let t0 = std::time::Instant::now();
    let (dist, next) = query::solve_next_hops(&g);
    let solve_s = t0.elapsed().as_secs_f64();
    let next_hop_bits = next.width_bits();
    let cell = SnapshotCell::new(Arc::new(QuerySnapshot::new(0, dist, next)));
    let snapshot_bytes = cell.load().bytes();
    println!(
        "serve workload: n={} m={}, {next_hop_bits}-bit next-hop map, snapshot {} B, \
         next-hop solve {}\n",
        g.n(),
        g.m(),
        snapshot_bytes,
        fmt_time(solve_s),
    );

    const BATCH: usize = 256;
    const DRAINS: usize = 64;
    let mut rng = Rng::new(seed);
    let mixed: Vec<QueryReq> = (0..BATCH)
        .map(|i| {
            let u = rng.gen_range(n) as u32;
            let v = rng.gen_range(n) as u32;
            let query = match i % 10 {
                0..=3 => Query::Dist { u, v },
                4..=6 => Query::Path { u, v },
                7..=8 => Query::KNearest { u, k: 8 },
                _ => Query::Reach { u },
            };
            QueryReq {
                tenant: (i % 3) as u16,
                query,
            }
        })
        .collect();
    let paths: Vec<QueryReq> = (0..BATCH)
        .map(|_| QueryReq {
            tenant: 0,
            query: Query::Path {
                u: rng.gen_range(n) as u32,
                v: rng.gen_range(n) as u32,
            },
        })
        .collect();

    let mut exec = BatchExec::new(8);
    let snap = cell.load();
    for _ in 0..4 {
        std::hint::black_box(exec.run(&snap, &mixed)); // warm the arena pools
    }
    let mut drain_lat = Vec::with_capacity(DRAINS);
    let t1 = std::time::Instant::now();
    for _ in 0..DRAINS {
        let t = std::time::Instant::now();
        std::hint::black_box(exec.run(&snap, &mixed));
        drain_lat.push(t.elapsed().as_secs_f64());
    }
    let qps = (DRAINS * BATCH) as f64 / t1.elapsed().as_secs_f64();
    let (p50, p90, p99) = (
        percentile(&drain_lat, 0.50),
        percentile(&drain_lat, 0.90),
        percentile(&drain_lat, 0.99),
    );

    // batched path reconstruction vs per-query Dijkstra on the same
    // workload shape — the ≥10× acceptance metric
    for _ in 0..4 {
        std::hint::black_box(exec.run(&snap, &paths));
    }
    let t2 = std::time::Instant::now();
    for _ in 0..DRAINS {
        std::hint::black_box(exec.run(&snap, &paths));
    }
    let path_per_query_s = t2.elapsed().as_secs_f64() / (DRAINS * BATCH) as f64;
    let t3 = std::time::Instant::now();
    let dij_sources = 16usize;
    for i in 0..dij_sources {
        std::hint::black_box(dijkstra::sssp(&g, (i * 37) % n));
    }
    let dijkstra_per_query_s = t3.elapsed().as_secs_f64() / dij_sources as f64;
    let path_speedup = dijkstra_per_query_s / path_per_query_s;
    drop(snap);

    // concurrent delta repair: reader threads hammer the cell while the
    // writer re-solves a 1%-reweighted graph and epoch-swaps it in
    let edges: Vec<(u32, u32, f32)> = g.edges().filter(|&(u, v, _)| u < v).collect();
    let loads = AtomicU64::new(0);
    let torn = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    const SWAPS: u64 = 3;
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    let snap = cell.load();
                    if !snap.verify() {
                        torn.fetch_add(1, Ordering::Relaxed);
                    }
                    loads.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        let mut cur = g.clone();
        for epoch in 1..=SWAPS {
            // k distinct edges: partial Fisher-Yates over indices
            let k = (edges.len() / 100).max(1);
            let mut idx: Vec<usize> = (0..edges.len()).collect();
            for i in 0..k {
                let j = i + rng.gen_range(idx.len() - i);
                idx.swap(i, j);
            }
            let batch: Vec<EdgeDelta> = idx[..k]
                .iter()
                .map(|&e| {
                    let (u, v, w) = edges[e];
                    EdgeDelta::Reweight { u, v, w: w * 0.99 }
                })
                .collect();
            cur = delta::apply_deltas(&cur, &batch);
            let (d2, n2) = query::solve_next_hops(&cur);
            cell.swap(Arc::new(QuerySnapshot::new(epoch, d2, n2)));
        }
        stop.store(true, Ordering::Relaxed);
    });
    let (reader_loads, torn_reads) = (loads.into_inner(), torn.into_inner());
    let swap_stalls = cell.stalls();

    let mut t = Table::new(
        "batched query serving (wall clock)",
        &["metric", "value"],
    );
    t.row(&["serve_qps (mixed)".to_string(), format!("{qps:.3e} QPS")]);
    t.row(&["drain p50 / p90 / p99".to_string(),
        format!("{} / {} / {}", fmt_time(p50), fmt_time(p90), fmt_time(p99))]);
    t.row(&["path per query (batched)".to_string(), fmt_time(path_per_query_s)]);
    t.row(&["Dijkstra per query".to_string(), fmt_time(dijkstra_per_query_s)]);
    t.row(&["path_speedup_vs_dijkstra".to_string(), fmt_ratio(path_speedup)]);
    t.row(&["snapshot swaps / stalls".to_string(), format!("{SWAPS} / {swap_stalls}")]);
    t.row(&["reader loads mid-swap".to_string(), reader_loads.to_string()]);
    t.row(&["torn_reads".to_string(), torn_reads.to_string()]);
    t.print();
    println!();

    if let Some(path) = json_out {
        BenchDoc::new("serve_nws1024")
            .count("graph_n", g.n())
            .count("graph_m", g.m())
            .count("next_hop_bits", next_hop_bits)
            .count("snapshot_bytes", snapshot_bytes)
            .num("host_next_hop_solve_s", solve_s)
            .num("qps", qps)
            .num("latency_p50_s", p50)
            .num("latency_p90_s", p90)
            .num("latency_p99_s", p99)
            .num("path_per_query_s", path_per_query_s)
            .num("dijkstra_per_query_s", dijkstra_per_query_s)
            .num("path_speedup_vs_dijkstra", path_speedup)
            .count("snapshot_swaps", SWAPS as usize)
            .count("snapshot_swap_stalls", swap_stalls as usize)
            .count("reader_loads", reader_loads as usize)
            .count("torn_reads", torn_reads as usize)
            .write(path)
            .expect("write serve bench json");
        println!("wrote {path}\n");
    }
}

/// Host hot-path throughput snapshot: the microkernel rates and the
/// scheduler dispatch overhead that PR's host-wall-clock work targets.
/// All of these are machine-dependent, so CI records them for trend
/// inspection but never drift-gates them (see `.github/workflows/ci.yml`).
struct HostPerf {
    /// Dispatched (SIMD-capable) row-wise FW, Gmadd/s at n=256.
    fw_gmadds_per_s: f64,
    /// Scalar-oracle triple loop on the same matrix.
    fw_scalar_gmadds_per_s: f64,
    /// Blocked min-plus microkernel, Gmadd/s at m=k=n=256.
    minplus_gmadds_per_s: f64,
    /// Scalar-oracle one-row-at-a-time min-plus.
    minplus_scalar_gmadds_per_s: f64,
    /// Per-task overhead of the batched-dequeue DAG executor on
    /// trivial tasks (pure scheduling cost).
    dispatch_ns_per_task: f64,
    /// Which relax microkernel the dispatch resolved to.
    kernel: &'static str,
}

fn measure_host_perf(opts: BenchOpts) -> HostPerf {
    let n = 256usize;
    let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 5.0), 0x5EED);
    let base = g.to_dense();
    // steady state: caller-held matrix + pivot scratch, no per-call heap
    let mut d = base.clone();
    let mut row_k = vec![0f32; n];
    let m_fw = bench(opts, || {
        d.as_mut_slice().copy_from_slice(base.as_slice());
        floyd_warshall::fw_rowwise_scratch(&mut d, &mut row_k);
        std::hint::black_box(d.get(0, 1));
    });
    let m_fw_scalar = bench(opts, || {
        d.as_mut_slice().copy_from_slice(base.as_slice());
        floyd_warshall::fw_inplace(&mut d);
        std::hint::black_box(d.get(0, 1));
    });

    let mut rng = Rng::new(0x5EED);
    let mk = |rng: &mut Rng| -> Vec<f32> {
        (0..n * n)
            .map(|_| {
                if rng.gen_bool(0.2) {
                    f32::INFINITY
                } else {
                    rng.gen_f32_range(0.0, 9.0)
                }
            })
            .collect()
    };
    let a = mk(&mut rng);
    let b = mk(&mut rng);
    let mut c = vec![f32::INFINITY; n * n];
    let m_mp = bench(opts, || {
        c.fill(f32::INFINITY);
        rapid_graph::apsp::minplus::minplus_into(&mut c, &a, &b, n, n, n);
        std::hint::black_box(c[0]);
    });
    let m_mp_scalar = bench(opts, || {
        c.fill(f32::INFINITY);
        rapid_graph::apsp::minplus::minplus_into_scalar(&mut c, &a, &b, n, n, n);
        std::hint::black_box(c[0]);
    });

    // scheduler dispatch: a wide DAG of no-op tasks isolates the
    // ready-queue cost per task (batched pops amortize the lock)
    let tasks = 4096usize;
    let deps: Vec<Vec<u32>> = vec![Vec::new(); tasks];
    let m_dispatch = bench(opts, || {
        threads::par_dag(&deps, |i| {
            std::hint::black_box(i);
        });
    });

    let gmadds = |secs: f64| (n as f64).powi(3) / secs / 1e9;
    HostPerf {
        fw_gmadds_per_s: gmadds(m_fw.mean_secs()),
        fw_scalar_gmadds_per_s: gmadds(m_fw_scalar.mean_secs()),
        minplus_gmadds_per_s: gmadds(m_mp.mean_secs()),
        minplus_scalar_gmadds_per_s: gmadds(m_mp_scalar.mean_secs()),
        dispatch_ns_per_task: m_dispatch.mean_secs() / tasks as f64 * 1e9,
        kernel: floyd_warshall::relax_kernel_name(),
    }
}

impl HostPerf {
    fn json_fields(&self) -> Vec<(&'static str, rapid_graph::util::json::Json)> {
        use rapid_graph::util::json;
        vec![
            ("host_relax_kernel", json::s(self.kernel)),
            ("host_fw_gmadds_per_s", json::num(self.fw_gmadds_per_s)),
            (
                "host_fw_scalar_gmadds_per_s",
                json::num(self.fw_scalar_gmadds_per_s),
            ),
            (
                "host_fw_speedup_vs_scalar",
                json::num(self.fw_gmadds_per_s / self.fw_scalar_gmadds_per_s),
            ),
            (
                "host_minplus_gmadds_per_s",
                json::num(self.minplus_gmadds_per_s),
            ),
            (
                "host_minplus_scalar_gmadds_per_s",
                json::num(self.minplus_scalar_gmadds_per_s),
            ),
            (
                "host_minplus_speedup_vs_scalar",
                json::num(self.minplus_gmadds_per_s / self.minplus_scalar_gmadds_per_s),
            ),
            (
                "host_dispatch_ns_per_task",
                json::num(self.dispatch_ns_per_task),
            ),
        ]
    }
}

/// With `--features count_alloc`: run the warmed tile-task kernels
/// (row-wise FW on held scratch, arena-backed FW, blocked min-plus) and
/// assert the steady state performs **zero** heap allocations. Returns
/// the counted allocations across the measured loop.
#[cfg(feature = "count_alloc")]
fn assert_alloc_free_steady_state() -> u64 {
    let n = 192usize;
    let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 5.0), 0xA110C);
    let base = g.to_dense();
    let mut d = base.clone();
    let mut row_k = vec![0f32; n];
    let mut rng = Rng::new(0xA110C);
    let a: Vec<f32> = (0..n * n).map(|_| rng.gen_f32_range(0.0, 9.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.gen_f32_range(0.0, 9.0)).collect();
    let mut c = vec![f32::INFINITY; n * n];
    let mut steady = || {
        // caller-scratch FW (the blocked backend's shape)
        d.as_mut_slice().copy_from_slice(base.as_slice());
        floyd_warshall::fw_rowwise_scratch(&mut d, &mut row_k);
        // arena-scratch FW (the tile task's shape): the pivot row is
        // leased from the warmed thread pool, not the allocator
        d.as_mut_slice().copy_from_slice(base.as_slice());
        floyd_warshall::fw_rowwise(&mut d);
        // blocked min-plus into a held accumulator
        c.fill(f32::INFINITY);
        rapid_graph::apsp::minplus::minplus_into(&mut c, &a, &b, n, n, n);
        std::hint::black_box((d.get(0, 1), c[0]));
    };
    steady(); // warm the arena free lists
    let before = alloc_count::allocs();
    for _ in 0..8 {
        steady();
    }
    let counted = alloc_count::allocs() - before;
    assert_eq!(
        counted, 0,
        "steady-state kernel loop allocated {counted} times; the tile arena \
         or the scratch threading regressed"
    );
    counted
}

/// `--host-perf`: per-kernel host throughput snapshot (the CI
/// perf-snapshot job runs this next to `--admission-only`). With
/// `--json PATH` the numbers land in a machine-readable artifact; with
/// `--features count_alloc` the allocation-free steady state is asserted
/// and recorded.
fn bench_host_perf(json_out: Option<&str>) {
    let hp = measure_host_perf(BenchOpts::default());
    let mut t = Table::new(
        "host hot-path kernels (n=256, per call)",
        &["metric", "value"],
    );
    t.row(&["relax kernel".into(), hp.kernel.into()]);
    t.row(&[
        "FW rowwise".into(),
        format!("{:.2} Gmadd/s", hp.fw_gmadds_per_s),
    ]);
    t.row(&[
        "FW scalar oracle".into(),
        format!("{:.2} Gmadd/s", hp.fw_scalar_gmadds_per_s),
    ]);
    t.row(&[
        "FW speedup vs scalar".into(),
        fmt_ratio(hp.fw_gmadds_per_s / hp.fw_scalar_gmadds_per_s),
    ]);
    t.row(&[
        "min-plus blocked".into(),
        format!("{:.2} Gmadd/s", hp.minplus_gmadds_per_s),
    ]);
    t.row(&[
        "min-plus speedup vs scalar".into(),
        fmt_ratio(hp.minplus_gmadds_per_s / hp.minplus_scalar_gmadds_per_s),
    ]);
    t.row(&[
        "DAG dispatch".into(),
        format!("{:.0} ns/task", hp.dispatch_ns_per_task),
    ]);
    t.print();

    #[cfg(feature = "count_alloc")]
    let steady_allocs = Some(assert_alloc_free_steady_state());
    #[cfg(not(feature = "count_alloc"))]
    let steady_allocs: Option<u64> = None;
    match steady_allocs {
        Some(0) => println!("allocation-free steady state: OK (counting allocator)\n"),
        Some(k) => println!("steady-state allocations: {k} (unexpected)\n"),
        None => {
            println!("allocation counting off (rerun with --features count_alloc to assert)\n")
        }
    }

    if let Some(path) = json_out {
        let mut doc = BenchDoc::new("host_perf_n256").extend_fields(hp.json_fields());
        if let Some(k) = steady_allocs {
            doc = doc.count("steady_state_allocs", k as usize);
        }
        doc.write(path).expect("write host-perf json");
        println!("wrote {path}\n");
    }
}

/// The store metric of the perf snapshot: hits, with-store makespan,
/// and the no-store makespan of the identical workload (verdicts match
/// by construction, so the ratio is apples-to-apples).
fn store_metrics(hw: &HwParams) -> (usize, f64, f64) {
    let g = generators::generate(Topology::Nws, 600, 8.0, Weights::Uniform(1.0, 5.0), 27);
    let plan = build_plan(
        &g,
        PlanOptions {
            tile_limit: 128,
            max_depth: usize::MAX,
            seed: 27,
        },
    );
    let subs: Vec<(&CsrGraph, &ApspPlan)> = vec![(&g, &plan); 3];
    let arrivals = vec![0.0, 1e-4, 2e-4];
    let cfg = AdmissionConfig {
        queue_depth: 1,
        ..AdmissionConfig::default()
    };
    let mut store = MemoryStore::new(8, 1 << 32);
    let (adm, outcomes) =
        AdmissionGraph::build_with_store(&subs, &arrivals, &cfg, &mut store, true);
    let hits = outcomes.iter().flatten().filter(|o| o.is_hit()).count();
    let (rep, _) = engine::simulate_admission(&adm.batch, &adm.arrivals, cfg.queue_depth, hw);
    let plain = AdmissionGraph::build(&subs, &arrivals, &cfg);
    let (plain_rep, _) =
        engine::simulate_admission(&plain.batch, &plain.arrivals, cfg.queue_depth, hw);
    (hits, rep.seconds, plain_rep.seconds)
}

/// Per-semiring kernel snapshot: the generic row-wise FW pass timed at
/// n=256 for each shipped semiring, with a deterministic bit-identity
/// check against a naive ⊕/⊗ triple loop on the same workload matrix
/// (the `*_oracle_max_diff` keys must be exactly zero). MaxPlus runs on
/// the DAG orientation of the workload graph — `(max, +)` closure
/// diverges on cycles. With `--json PATH` the numbers land in
/// `BENCH_semiring.json` through the shared `BenchDoc` builder: the
/// oracle-diff ceilings are hard gates (deterministic on any machine),
/// the Gmadd/s floors are loose sanity bounds — wall-clock rates stay
/// trend-inspection only.
fn bench_semirings(json_out: Option<&str>) {
    use rapid_graph::apsp::semiring::{SemiringId, ALL_SEMIRINGS};

    let n = 256usize;
    let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 5.0), 0x5E81);
    let dag = g.dag_oriented();
    let opts = BenchOpts::quick();

    let mut t = Table::new(
        "semiring FW kernels (generic row-wise pass, n=256)",
        &["semiring", "wall time", "Gmadd/s", "oracle max_diff"],
    );
    let mut doc = BenchDoc::new("semiring_fw_n256").count("n", n);
    for sr in ALL_SEMIRINGS {
        let base = if sr == SemiringId::MaxPlus {
            dag.to_dense_sr(sr)
        } else {
            g.to_dense_sr(sr)
        };
        let mut d = base.clone();
        let m = bench(opts, || {
            d.as_mut_slice().copy_from_slice(base.as_slice());
            floyd_warshall::fw_rowwise_dyn(&mut d, sr);
            std::hint::black_box(d.get(0, 1));
        });
        // untimed scalar oracle: the naive in-place ⊕/⊗ triple loop
        let mut oracle = base.clone();
        for k in 0..n {
            for i in 0..n {
                let dik = oracle.get(i, k);
                if sr.is_absorbing(dik) {
                    continue;
                }
                for j in 0..n {
                    let via = sr.extend(dik, oracle.get(k, j));
                    oracle.set(i, j, sr.combine(oracle.get(i, j), via));
                }
            }
        }
        d.as_mut_slice().copy_from_slice(base.as_slice());
        floyd_warshall::fw_rowwise_dyn(&mut d, sr);
        let diff = d.max_diff(&oracle);
        assert_eq!(
            diff,
            0.0,
            "generic {} kernel diverged from the scalar oracle",
            sr.name()
        );
        let gmadds = (n as f64).powi(3) / m.mean_secs() / 1e9;
        t.row(&[
            sr.name().into(),
            fmt_time(m.mean_secs()),
            format!("{gmadds:.2}"),
            format!("{diff}"),
        ]);
        let tag = sr.name().replace('-', "_");
        let key_rate = format!("{tag}_fw_gmadds_per_s");
        let key_diff = format!("{tag}_oracle_max_diff");
        doc = doc
            .num(&key_rate, gmadds)
            .num(&key_diff, diff as f64)
            .ceiling(&format!("{key_diff}_max"), 0.0)
            .floor(&format!("{key_rate}_min"), 0.01);
    }
    t.print();

    if let Some(path) = json_out {
        doc.write(path).expect("write semiring bench json");
        println!("wrote {path}\n");
    }
}

fn main() {
    let args = rapid_graph::util::cli::Args::from_env();
    let json_out = args.get("json");
    if args.flag("admission-only") {
        // the CI perf-snapshot job: just the admission numbers, quick
        bench_admission(json_out);
        return;
    }
    if args.flag("host-perf") {
        // per-kernel host throughput (the other CI perf-snapshot step)
        bench_host_perf(json_out);
        return;
    }
    if args.flag("delta-only") {
        // the CI perf-snapshot job: the incremental-repair sweep
        bench_delta(json_out);
        return;
    }
    if args.flag("serve-only") {
        // the CI serve-snapshot job: the batched query-serving sweep
        bench_serve(json_out);
        return;
    }
    if args.flag("semiring-only") {
        // the CI perf-snapshot job: per-semiring kernel identity + rates
        bench_semirings(json_out);
        return;
    }
    bench_schedulers();
    bench_batching();
    bench_sharding();
    bench_admission(json_out);
    bench_delta(None);
    bench_serve(None);
    bench_host_perf(None);
    bench_semirings(None);

    let runtime = PjrtRuntime::load_default().ok();
    if runtime.is_none() {
        println!("note: artifacts missing, PJRT columns skipped (run `make artifacts`)\n");
    }

    // ---- FW blocks
    let mut t = Table::new(
        "FW block kernels (one full pass, per call)",
        &["n", "native serial", "native parallel", "pjrt", "native Gmadd/s"],
    );
    for &n in &[128usize, 256, 512, 1024] {
        let g = generators::newman_watts_strogatz(n, 5, 0.1, Weights::Uniform(1.0, 5.0), n as u64);
        let base = g.to_dense();
        let opts = if n >= 512 { BenchOpts::quick() } else { BenchOpts::default() };

        let m_serial = bench(opts, || {
            let mut d = base.clone();
            floyd_warshall::fw_rowwise(&mut d);
            std::hint::black_box(d.get(0, 1));
        });
        let m_par = bench(opts, || {
            let mut d = base.clone();
            floyd_warshall::fw_parallel(&mut d);
            std::hint::black_box(d.get(0, 1));
        });
        let pjrt_cell = if let Some(rt) = &runtime {
            let m = bench(opts, || {
                let mut d = base.clone();
                rt.fw_block(&mut d).unwrap();
                std::hint::black_box(d.get(0, 1));
            });
            fmt_time(m.mean_secs())
        } else {
            "-".to_string()
        };
        let gmadds = (n as f64).powi(3) / m_par.mean_secs() / 1e9;
        t.row(&[
            n.to_string(),
            fmt_time(m_serial.mean_secs()),
            fmt_time(m_par.mean_secs()),
            pjrt_cell,
            format!("{gmadds:.2}"),
        ]);
    }
    t.print();

    // ---- min-plus merges
    let mut t = Table::new(
        "min-plus merge kernels (C = min(C, A (+) B), per call)",
        &["m=k=n", "native serial", "native parallel", "pjrt"],
    );
    let be = NativeBackend;
    for &n in &[128usize, 256, 512, 1024] {
        let mut rng = Rng::new(n as u64);
        let gen = |rng: &mut Rng| -> Vec<f32> {
            (0..n * n)
                .map(|_| {
                    if rng.gen_bool(0.2) {
                        f32::INFINITY
                    } else {
                        rng.gen_f32_range(0.0, 9.0)
                    }
                })
                .collect()
        };
        let a = gen(&mut rng);
        let b = gen(&mut rng);
        let c0 = vec![f32::INFINITY; n * n];
        let opts = if n >= 512 { BenchOpts::quick() } else { BenchOpts::default() };
        let m_serial = bench(opts, || {
            let mut c = c0.clone();
            rapid_graph::apsp::minplus::minplus_into(&mut c, &a, &b, n, n, n);
            std::hint::black_box(c[0]);
        });
        let m_par = bench(opts, || {
            let mut c = c0.clone();
            be.minplus_into(&mut c, &a, &b, n, n, n);
            std::hint::black_box(c[0]);
        });
        let pjrt_cell = if let Some(rt) = &runtime {
            let m = bench(opts, || {
                let mut c = c0.clone();
                rt.minplus_into(&mut c, &a, &b, n, n, n).unwrap();
                std::hint::black_box(c[0]);
            });
            fmt_time(m.mean_secs())
        } else {
            "-".to_string()
        };
        t.row(&[
            n.to_string(),
            fmt_time(m_serial.mean_secs()),
            fmt_time(m_par.mean_secs()),
            pjrt_cell,
        ]);
    }
    t.print();
}
