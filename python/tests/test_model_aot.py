"""L2 model shape checks + AOT lowering smoke tests."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model


def test_fw_tile_returns_tuple():
    d = jnp.zeros((8, 8), jnp.float32)
    out = model.fw_tile(d)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (8, 8)


def test_mp_tile_returns_tuple():
    x = jnp.zeros((8, 8), jnp.float32)
    out = model.mp_tile(x, x, x)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (8, 8)


@pytest.mark.parametrize("n", [64, 128])
def test_lowered_fw_has_expected_signature(n):
    text = aot.lower_fw(n)
    assert f"f32[{n},{n}]" in text
    assert "ENTRY" in text


@pytest.mark.parametrize("n", [64])
def test_lowered_minplus_has_expected_signature(n):
    text = aot.lower_minplus(n)
    # three params of the same shape
    assert text.count(f"f32[{n},{n}]") >= 3


def test_lowering_deterministic():
    assert aot.lower_fw(64) == aot.lower_fw(64)


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    import os

    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--sizes", "64"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    kinds = {(a["kind"], a["n"]) for a in manifest["artifacts"]}
    assert kinds == {("fw", 64), ("minplus", 64)}
    for a in manifest["artifacts"]:
        assert (out / a["path"]).exists()


def test_fw_tile_numerics_through_jit():
    rng = np.random.default_rng(0)
    d = rng.uniform(1, 5, (16, 16)).astype(np.float32)
    np.fill_diagonal(d, 0)
    got = np.asarray(model.fw_tile(jnp.asarray(d))[0])
    # brute-force check
    want = d.copy()
    for k in range(16):
        want = np.minimum(want, want[:, k : k + 1] + want[k : k + 1, :])
    np.testing.assert_allclose(got, want, rtol=1e-6)
