"""Pallas FW kernel vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fw, ref

INF = np.float32(np.inf)


def random_dist_block(rng, n, inf_frac=0.5, wmax=10.0):
    """Random distance block: +inf off-diagonal holes, zero diagonal."""
    d = rng.uniform(0.5, wmax, size=(n, n)).astype(np.float32)
    holes = rng.uniform(size=(n, n)) < inf_frac
    d[holes] = INF
    np.fill_diagonal(d, 0.0)
    return d


def numpy_fw(d):
    d = d.copy()
    n = d.shape[0]
    for k in range(n):
        d = np.minimum(d, d[:, k : k + 1] + d[k : k + 1, :])
    return d


@pytest.mark.parametrize("n", [2, 3, 8, 16, 33, 64])
def test_matches_numpy_oracle(n):
    rng = np.random.default_rng(n)
    d = random_dist_block(rng, n)
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    want = numpy_fw(d)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [4, 32, 100])
def test_matches_jnp_reference(n):
    rng = np.random.default_rng(n + 1000)
    d = random_dist_block(rng, n, inf_frac=0.3)
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    want = np.asarray(ref.fw_reference(jnp.asarray(d)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_disconnected_stays_inf():
    d = np.full((6, 6), INF, np.float32)
    np.fill_diagonal(d, 0.0)
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    assert np.isinf(got[np.triu_indices(6, 1)]).all()
    assert (np.diag(got) == 0).all()


def test_known_three_node_shortcut():
    d = np.array(
        [[0, 1, 5], [INF, 0, 2], [INF, INF, 0]],
        np.float32,
    )
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    assert got[0, 2] == 3.0  # via vertex 1
    assert np.isinf(got[2, 0])  # directed


def test_idempotent():
    rng = np.random.default_rng(7)
    d = random_dist_block(rng, 24)
    once = np.asarray(fw.fw_block(jnp.asarray(d)))
    twice = np.asarray(fw.fw_block(jnp.asarray(once)))
    np.testing.assert_allclose(once, twice, rtol=1e-6, atol=1e-5)


@settings(deadline=None, max_examples=25)
@given(
    n=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
    inf_frac=st.floats(min_value=0.0, max_value=0.95),
)
def test_hypothesis_sweep(n, seed, inf_frac):
    rng = np.random.default_rng(seed)
    d = random_dist_block(rng, n, inf_frac=inf_frac)
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    want = numpy_fw(d)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # triangle inequality on finite entries
    for k in range(n):
        cand = got[:, k : k + 1] + got[k : k + 1, :]
        assert (got <= cand + 1e-4).all() | np.isinf(cand).any()


@settings(deadline=None, max_examples=10)
@given(
    n=st.sampled_from([3, 7, 16]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_symmetric_input_symmetric_output(n, seed):
    rng = np.random.default_rng(seed)
    d = random_dist_block(rng, n, inf_frac=0.4)
    d = np.minimum(d, d.T)  # symmetrize
    got = np.asarray(fw.fw_block(jnp.asarray(d)))
    np.testing.assert_allclose(got, got.T, rtol=1e-6)
