"""Pallas min-plus kernel vs oracle, including ragged/padded shapes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import minplus, ref

INF = np.float32(np.inf)


def rand(rng, shape, inf_frac=0.3, wmax=9.0):
    x = rng.uniform(0.0, wmax, size=shape).astype(np.float32)
    x[rng.uniform(size=shape) < inf_frac] = INF
    return x


def numpy_minplus(c, a, b):
    cand = (a[:, :, None] + b[None, :, :]).min(axis=1)
    return np.minimum(c, cand)


@pytest.mark.parametrize(
    "m,k,n",
    [(2, 2, 2), (4, 8, 4), (16, 16, 16), (32, 64, 32), (128, 128, 128), (5, 3, 7)],
)
def test_matches_numpy(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    c = rand(rng, (m, n), inf_frac=0.7)
    got = np.asarray(minplus.minplus_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, numpy_minplus(c, a, b), rtol=1e-6, atol=1e-6)


def test_accumulates_against_existing():
    c = np.array([[1.0]], np.float32)
    a = np.array([[2.0]], np.float32)
    b = np.array([[3.0]], np.float32)
    got = np.asarray(minplus.minplus_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    assert got[0, 0] == 1.0  # existing 1 < 5


def test_all_inf_identity():
    rng = np.random.default_rng(1)
    c = rand(rng, (8, 8), inf_frac=0.0)
    a = np.full((8, 8), INF, np.float32)
    b = rand(rng, (8, 8))
    got = np.asarray(minplus.minplus_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, c)


def test_matches_jnp_reference_large():
    rng = np.random.default_rng(2)
    m = k = n = 256
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    c = np.full((m, n), INF, np.float32)
    got = np.asarray(minplus.minplus_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.minplus_reference(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_padding_with_inf_is_safe():
    """Padding A/B/C to tile size with +inf must not change the valid
    corner — the property the rust runtime's padding relies on."""
    rng = np.random.default_rng(3)
    m, k, n = 10, 13, 9
    a, b = rand(rng, (m, k)), rand(rng, (k, n))
    c = np.full((m, n), INF, np.float32)
    small = numpy_minplus(c, a, b)

    P = 32
    ap = np.full((P, P), INF, np.float32)
    bp = np.full((P, P), INF, np.float32)
    cp = np.full((P, P), INF, np.float32)
    ap[:m, :k], bp[:k, :n] = a, b
    got = np.asarray(
        minplus.minplus_accum(jnp.asarray(cp), jnp.asarray(ap), jnp.asarray(bp))
    )
    np.testing.assert_allclose(got[:m, :n], small, rtol=1e-6, atol=1e-6)
    assert np.isinf(got[m:, :]).all()


@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31),
    inf_frac=st.floats(0.0, 1.0),
)
def test_hypothesis_sweep(m, k, n, seed, inf_frac):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, (m, k), inf_frac), rand(rng, (k, n), inf_frac)
    c = rand(rng, (m, n), inf_frac=0.8)
    got = np.asarray(minplus.minplus_accum(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, numpy_minplus(c, a, b), rtol=1e-6, atol=1e-6)


def test_two_stage_merge_composes():
    """Chaining two kernel calls == the paper's two-stage merge."""
    rng = np.random.default_rng(5)
    m, b1, b2, n = 8, 4, 6, 10
    a = rand(rng, (m, b1))
    db = rand(rng, (b1, b2))
    bb = rand(rng, (b2, n))
    s1 = np.asarray(
        minplus.minplus_accum(
            jnp.full((m, b2), INF), jnp.asarray(a), jnp.asarray(db)
        )
    )
    s2 = np.asarray(
        minplus.minplus_accum(
            jnp.full((m, n), INF), jnp.asarray(s1), jnp.asarray(bb)
        )
    )
    want = np.asarray(
        ref.two_stage_reference(jnp.asarray(a), jnp.asarray(db), jnp.asarray(bb))
    )
    np.testing.assert_allclose(s2, want, rtol=1e-6, atol=1e-6)
