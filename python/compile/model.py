"""Layer-2 JAX model: the tile-granular compute graph the rust
coordinator executes through PJRT.

Two exported computations, one per PIM die:

* ``fw_tile``    — full Floyd-Warshall over one tile block
                   (the PCM-FW die's job, paper Fig. 6c).
* ``mp_tile``    — accumulating min-plus product C = min(C, A (+) B)
                   (one stage of the PCM-MP die's two-stage merge,
                   Fig. 6d; the coordinator chains two calls for the
                   full merge, exactly as the hardware does).

Both call the Layer-1 Pallas kernels, so the kernels lower into the same
HLO module that ships to rust. Python never runs at serve time — these
functions exist to be AOT-lowered by ``aot.py``.
"""

from .kernels import fw as fw_kernel
from .kernels import minplus as mp_kernel


def fw_tile(d):
    """APSP of one dense tile block (n x n, f32, +inf = no edge)."""
    return (fw_kernel.fw_block(d),)


def mp_tile(c, a, b):
    """One accumulating min-plus stage over square tile blocks."""
    return (mp_kernel.minplus_accum(c, a, b),)
