"""AOT lowering: JAX/Pallas tile computations -> HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
HLO text through the `xla` crate's PJRT CPU client. HLO *text* (not
serialized HloModuleProto) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (one per tile size class, paper tile limit = 1024):
  fw_block_{n}.hlo.txt   fw_tile  : f32[n,n] -> (f32[n,n],)
  minplus_{n}.hlo.txt    mp_tile  : f32[n,n] x3 -> (f32[n,n],)
  manifest.json          machine-readable index for the rust loader
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

SIZES = [64, 128, 256, 512, 1024]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fw(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jax.numpy.float32)
    return to_hlo_text(jax.jit(model.fw_tile).lower(spec))


def lower_minplus(n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jax.numpy.float32)
    return to_hlo_text(jax.jit(model.mp_tile).lower(spec, spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in SIZES),
        help="comma-separated tile sizes",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out, exist_ok=True)

    artifacts = []
    for n in sizes:
        for kind, lower in (("fw", lower_fw), ("minplus", lower_minplus)):
            name = f"fw_block_{n}.hlo.txt" if kind == "fw" else f"minplus_{n}.hlo.txt"
            path = os.path.join(args.out, name)
            text = lower(n)
            with open(path, "w") as f:
                f.write(text)
            artifacts.append({"kind": kind, "n": n, "path": name})
            print(f"wrote {name} ({len(text)} chars)", file=sys.stderr)

    manifest = {
        "artifacts": artifacts,
        "jax_version": jax.__version__,
        "interchange": "hlo-text",
        "return_tuple": True,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(artifacts)} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
