"""Layer-1 Pallas kernel: in-place Floyd-Warshall over one tile block.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's PCM-FW
tile peels the pivot row/column into Panel_Row / Panel_Col and updates the
Main_Block with one bit-serial add + one bit-serial min per pivot
(Fig. 6b/c). On a vector machine the same insight becomes a rank-1
min-plus outer update: broadcast the pivot row against the pivot column
and take the elementwise minimum with the block. The block stays resident
(VMEM on a real TPU; the paper's PCM array) across all n pivots — the
grid axis *is* the pivot loop, and `input_output_aliases` gives the same
in-place semantics as the paper's selective sign-bit write.

The kernel is lowered with ``interpret=True`` so it compiles to plain HLO
the CPU PJRT client can execute (a real-TPU build would emit a Mosaic
custom-call instead; see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fw_pivot_kernel(d_ref, o_ref):
    """One pivot step: O = min(D, D[:, k] + D[k, :]).

    d_ref is the aliased input block (same buffer as o_ref); reading
    o_ref gives the current state after previous pivots because pallas
    grid steps execute sequentially.
    """
    k = pl.program_id(0)
    d = o_ref[...]
    # Panel extraction (paper Fig. 6b): pivot row and mirrored pivot col.
    row_k = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, n)
    col_k = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (n, 1)
    # Main_Block update: one add, one min (Fig. 6c).
    o_ref[...] = jnp.minimum(d, col_k + row_k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fw_block(d, interpret=True):
    """Full Floyd-Warshall pass over a square f32 block, in place.

    Args:
      d: (n, n) float32 distance block; +inf marks "no edge". The
        diagonal must be 0 for the pivot-peeling identity to hold (the
        paper's remapping makes the same assumption: "diagonal pivot
        elements p_k always have zero distance").
    Returns:
      The exact all-pairs shortest-path matrix of the block.
    """
    n = d.shape[0]
    assert d.shape == (n, n), f"square block required, got {d.shape}"
    return pl.pallas_call(
        _fw_pivot_kernel,
        grid=(n,),
        out_shape=jax.ShapeDtypeStruct((n, n), d.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(d)
