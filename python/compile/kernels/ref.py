"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantics the kernels must reproduce exactly; pytest +
hypothesis sweep shapes, weights and infinity patterns against them.
"""

import jax
import jax.numpy as jnp


@jax.jit
def fw_reference(d):
    """Textbook Floyd-Warshall via lax.fori_loop (paper §II-B1)."""
    n = d.shape[0]

    def body(k, dist):
        row_k = jax.lax.dynamic_slice_in_dim(dist, k, 1, axis=0)
        col_k = jax.lax.dynamic_slice_in_dim(dist, k, 1, axis=1)
        return jnp.minimum(dist, col_k + row_k)

    return jax.lax.fori_loop(0, n, body, d)


@jax.jit
def minplus_reference(c, a, b):
    """C = min(C, A (+) B) by direct broadcast (small shapes only)."""
    cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    return jnp.minimum(c, cand)


def two_stage_reference(a, db, b):
    """min_{i,j}(A[m,i] + DB[i,j] + B[j,n]) — paper Fig. 6d semantics."""
    m = a.shape[0]
    b2 = db.shape[1]
    inf = jnp.full((m, b2), jnp.inf, a.dtype)
    stage1 = minplus_reference(inf, a, db)
    n = b.shape[1]
    inf2 = jnp.full((m, n), jnp.inf, a.dtype)
    return minplus_reference(inf2, stage1, b)
