"""Layer-1 Pallas kernel: blocked min-plus (tropical) matmul.

The paper's PCM-MP tile streams 1024-wide rows through bit-serial adders
and a 6-level comparator tree (Fig. 5e / 6d). The TPU-shaped equivalent
tiles C over a (i, j, k) grid: each grid step loads an (bm, bk) A-tile
and (bk, bn) B-tile into VMEM, evaluates all bm*bk*bn min-add candidates,
and lane-reduces over k — the comparator tree becomes `jnp.min` over the
contraction axis, and the paper's compare-and-swap selective write
becomes the accumulating `minimum` against the aliased C block.

C is aliased in/out, so the op computes C = min(C, A (+) B) — the
accumulate form Algorithm 1 step 4 needs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_kernel(c_in_ref, a_ref, b_ref, c_ref):
    del c_in_ref  # aliased with c_ref; reads go through c_ref
    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    # all candidates for this k-tile, reduced over the contraction axis
    cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)  # (bm, bn)
    c_ref[...] = jnp.minimum(c_ref[...], cand)


def _tile(n, pref):
    """Largest divisor of n that is <= pref (shapes here are powers of
    two, so this returns pref for n >= pref)."""
    t = min(n, pref)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_accum(c, a, b, interpret=True):
    """C = min(C, A (+) B) for row-major f32 matrices.

    Args:
      c: (m, n) accumulator (+inf where nothing merged yet).
      a: (m, k), b: (k, n).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n), f"dims: {a.shape} x {b.shape} -> {c.shape}"
    bm = _tile(m, 128)
    bn = _tile(n, 128)
    # bk = 128 keeps the (bm, bk, bn) candidate tensor at 8 MB while
    # cutting grid-step count 4x vs bk=32 — the dominant cost under the
    # XLA CPU while-loop (EXPERIMENTS.md §Perf L1/L2)
    bk = _tile(k, 128)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(c, a, b)
