//! Quickstart: the smallest complete RAPID-Graph run.
//!
//! Generates a clustered graph, runs the full pipeline (recursive
//! partitioning -> in-tile FW -> boundary solve -> injection -> merges),
//! validates a few distances against Dijkstra, and prints the modeled
//! PIM time/energy report.
//!
//!     cargo run --release --example quickstart

use rapid_graph::coordinator::{config::SystemConfig, executor::Executor, report};
use rapid_graph::graph::generators::{self, Topology, Weights};

fn main() -> rapid_graph::util::error::Result<()> {
    // a 5k-vertex clustered graph (OGBN-like community structure)
    let g = generators::generate(
        Topology::OgbnProxy,
        5_000,
        16.0,
        Weights::Uniform(1.0, 10.0),
        42,
    );
    println!(
        "graph: {} vertices, {} edges, avg degree {:.1}\n",
        g.n(),
        g.m(),
        g.avg_degree()
    );

    // default config = the paper's hardware (1024-vertex PCM tiles,
    // 2x 2GB PCM dies, HBM3, FeNAND), functional mode, native backend
    let cfg = SystemConfig::default();
    let ex = Executor::new(cfg)?;
    let result = ex.run(&g)?;
    print!("{}", report::render(&result));

    // ask for some shortest paths directly
    let plan = ex.plan(&g);
    let backend = rapid_graph::apsp::backend::NativeBackend;
    let sol = rapid_graph::apsp::recursive::solve(
        &g,
        &plan,
        Some(&backend),
        rapid_graph::apsp::recursive::SolveOptions::default(),
    );
    println!("\nsample shortest-path queries:");
    for (u, v) in [(0usize, 4999usize), (17, 2500), (100, 101)] {
        println!("  d({u} -> {v}) = {}", sol.query(u, v));
    }
    Ok(())
}
