//! End-to-end driver — the full three-layer system on a real workload.
//!
//! This is the composition proof for the whole stack (DESIGN.md):
//!
//!   Layer 1/2 (JAX + Pallas, AOT)  — tile FW + min-plus HLO artifacts
//!   Runtime                        — rust PJRT client executes them
//!   Layer 3 (rust coordinator)     — recursive partitioning, dataflow,
//!                                    PIM simulation, validation
//!
//! Workload: a 20k-vertex / ~250k-edge clustered graph (OGBN-Products
//! proxy at 1/122 scale). The run:
//!   1. partitions it into <=1024-vertex components + boundary hierarchy,
//!   2. computes exact APSP with FW/MP tiles executed through **PJRT**
//!      (the AOT JAX/Pallas kernels — Python is not running!),
//!   3. cross-validates sampled distances against repeated Dijkstra,
//!   4. re-runs with the native backend and checks both engines agree,
//!   5. reports the modeled RAPID-Graph hardware time/energy vs the
//!      CPU/GPU baselines.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example end_to_end

use rapid_graph::apsp::backend::{NativeBackend, TileBackend};
use rapid_graph::apsp::recursive::{solve, SolveOptions};
use rapid_graph::apsp::validate::validate_sampled;
use rapid_graph::baselines::{cpu::CpuModel, gpu};
use rapid_graph::coordinator::config::{BackendKind, Mode, SystemConfig};
use rapid_graph::coordinator::{executor::Executor, report};
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::runtime::{PjrtBackend, PjrtRuntime};
use rapid_graph::util::table::{fmt_energy, fmt_ratio, fmt_time};

fn main() -> rapid_graph::util::error::Result<()> {
    let n = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000usize);
    println!("=== RAPID-Graph end-to-end driver (n={n}) ===\n");
    let g = generators::generate(
        Topology::OgbnProxy,
        n,
        25.25,
        Weights::Uniform(1.0, 8.0),
        2026,
    );
    println!(
        "[1/5] workload: OGBN-proxy, {} vertices, {} edges, avg degree {:.2}",
        g.n(),
        g.m(),
        g.avg_degree()
    );

    // ---- full pipeline through the PJRT backend (AOT JAX/Pallas HLO)
    let t0 = std::time::Instant::now();
    let runtime = PjrtRuntime::load_default().map_err(|e| {
        rapid_graph::err!("{e:#}\nhint: run `make artifacts` first")
    })?;
    println!(
        "[2/5] PJRT runtime up: {} artifacts (jax {}), compiled in {:.1}s",
        runtime.manifest.artifacts.len(),
        runtime.manifest.jax_version,
        t0.elapsed().as_secs_f64()
    );

    let mut cfg = SystemConfig::default();
    cfg.mode = Mode::Functional;
    cfg.backend = BackendKind::Pjrt;
    let ex = Executor::new(cfg.clone())?;
    let plan = ex.plan(&g);
    println!(
        "      plan: depth={} components={} boundary={:?} final_n={}",
        plan.depth(),
        plan.levels.first().map(|l| l.cs.components.len()).unwrap_or(1),
        plan.boundary_sizes(),
        plan.final_n,
    );

    let pjrt_backend = PjrtBackend::new(&runtime);
    let t1 = std::time::Instant::now();
    let sol_pjrt = solve(&g, &plan, Some(&pjrt_backend), SolveOptions::default());
    let pjrt_secs = t1.elapsed().as_secs_f64();
    println!("[3/5] exact APSP solved through PJRT in {}", fmt_time(pjrt_secs));

    // ---- validation vs Dijkstra
    let v = validate_sampled(&g, &sol_pjrt, 32, 64, 1e-3, 7);
    println!(
        "      validation vs Dijkstra: {} samples, max err {:.2e}, {} mismatches -> {}",
        v.checked,
        v.max_abs_err,
        v.mismatches,
        if v.ok(1e-3) { "EXACT" } else { "FAILED" }
    );
    assert!(v.ok(1e-3), "PJRT pipeline produced wrong distances!");

    // ---- cross-engine agreement (PJRT vs native rust kernels)
    let native = NativeBackend;
    let t2 = std::time::Instant::now();
    let sol_native = solve(&g, &plan, Some(&native), SolveOptions::default());
    let native_secs = t2.elapsed().as_secs_f64();
    let mut worst = 0f32;
    let mut rng = rapid_graph::util::rng::Rng::new(99);
    for _ in 0..2000 {
        let u = rng.gen_range(g.n());
        let w = rng.gen_range(g.n());
        let a = sol_pjrt.query(u, w);
        let b = sol_native.query(u, w);
        let d = if a.is_finite() || b.is_finite() {
            (a - b).abs()
        } else {
            0.0
        };
        worst = worst.max(d);
    }
    println!(
        "[4/5] engine agreement: PJRT vs native max |Δ| = {worst:.2e} over 2000 queries \
         (native solve {})",
        fmt_time(native_secs)
    );
    assert!(worst < 1e-3, "engines disagree");

    // ---- modeled hardware report + baselines
    let run = ex.run_with_plan(&g, &plan)?;
    println!("\n[5/5] modeled RAPID-Graph hardware:");
    print!("{}", report::render(&run));
    let cpu = CpuModel::calibrated();
    let cpu_cost = cpu.cost(g.n());
    let h100 = gpu::h100().cost(g.n());
    println!(
        "baselines at n={n}: CPU (host-calibrated) {} / {}, H100 (modeled) {} / {}",
        fmt_time(cpu_cost.seconds),
        fmt_energy(cpu_cost.joules),
        fmt_time(h100.seconds),
        fmt_energy(h100.joules),
    );
    println!(
        "RAPID-Graph vs CPU: {} faster, {} more energy-efficient",
        fmt_ratio(cpu_cost.seconds / run.sim.seconds),
        fmt_ratio(cpu_cost.joules / run.sim.joules),
    );
    println!(
        "RAPID-Graph vs H100: {} faster, {} more energy-efficient",
        fmt_ratio(h100.seconds / run.sim.seconds),
        fmt_ratio(h100.joules / run.sim.joules),
    );
    println!("\nend_to_end OK");
    Ok(())
}
