//! Multi-tenant batch serving scenario — the production-scale shape the
//! ROADMAP targets: many users submit independent graph workloads, and
//! the coordinator merges them into one shared-resource schedule
//! instead of running them back to back.
//!
//! Eight tenant graphs of mixed topology and size are submitted
//! together; the report shows each tenant's modeled solo latency, its
//! completion time inside the shared schedule, the batch utilization,
//! and the throughput gain over serial submission.
//!
//!     cargo run --release --example batch_serving

use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::table::{fmt_energy, fmt_ratio, fmt_time, Table};

fn main() -> rapid_graph::util::error::Result<()> {
    let tenants: [(&str, Topology, usize, f64); 8] = [
        ("rideshare-eu", Topology::Grid, 1_200, 4.0),
        ("social-feed", Topology::OgbnProxy, 1_500, 12.0),
        ("logistics", Topology::Nws, 900, 10.0),
        ("adhoc-analytics", Topology::Er, 700, 8.0),
        ("rideshare-us", Topology::Grid, 1_000, 4.0),
        ("fraud-graph", Topology::OgbnProxy, 800, 14.0),
        ("supply-chain", Topology::Nws, 1_300, 8.0),
        ("sandbox", Topology::Er, 500, 6.0),
    ];
    let graphs: Vec<_> = tenants
        .iter()
        .enumerate()
        .map(|(i, &(_, topo, n, degree))| {
            generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), 100 + i as u64)
        })
        .collect();

    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 256;
    let ex = Executor::new(cfg)?;
    println!(
        "submitting {} tenant graphs as one scheduled workload set...\n",
        graphs.len()
    );
    let b = ex.run_batch(&graphs)?;

    let mut t = Table::new(
        "per-tenant modeled latency (solo submission vs shared batch)",
        &["tenant", "n", "solo", "batch finish", "dyn energy", "valid"],
    );
    for (i, (r, s)) in b.per_graph.iter().zip(&b.batch_stats).enumerate() {
        t.row(&[
            tenants[i].0.to_string(),
            r.graph_n.to_string(),
            fmt_time(r.sim.seconds),
            fmt_time(s.makespan),
            fmt_energy(s.dynamic_joules),
            match &r.validation {
                Some(v) if v.ok(r.validate_tolerance) => "EXACT".to_string(),
                Some(_) => "FAILED".to_string(),
                None => "-".to_string(),
            },
        ]);
    }
    t.print();

    println!(
        "batch makespan {} vs serial submission {} -> {} throughput",
        fmt_time(b.batch_sim.seconds),
        fmt_time(b.solo_makespan_sum()),
        fmt_ratio(b.batch_speedup()),
    );
    println!(
        "shared-die utilization: FW {:.1}%, MP {:.1}%; host numerics {}",
        100.0 * b.batch_sim.fw_utilization(),
        100.0 * b.batch_sim.mp_utilization(),
        fmt_time(b.host_solve_seconds),
    );
    Ok(())
}
