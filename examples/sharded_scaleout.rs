//! Sharded scale-out scenario — the ROADMAP's "one graph too big for
//! one stack" shape: an OGBN-proxy workload is partitioned across 1, 2,
//! 4, and 8 modeled PIM stacks, with the boundary recursion on a hub
//! stack and every cross-shard boundary/dB transfer serialized on the
//! inter-stack interconnect.
//!
//! Estimate mode (no host numerics) keeps the sweep cheap at a size
//! where one stack's channels are the bottleneck, so the table shows
//! the modeled makespan falling as stacks are added — and the
//! interconnect column shows the cross-shard traffic that eventually
//! caps the curve.
//!
//!     cargo run --release --example sharded_scaleout

use rapid_graph::coordinator::config::{Mode, SystemConfig};
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::coordinator::report;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::table::{fmt_count, fmt_ratio, fmt_time, Table};

fn main() -> rapid_graph::util::error::Result<()> {
    let n = 60_000;
    let g = generators::generate(Topology::OgbnProxy, n, 16.0, Weights::Uniform(1.0, 8.0), 7);
    println!(
        "OGBN-proxy scale-out workload: n={} m={} (estimate mode)\n",
        fmt_count(g.n()),
        fmt_count(g.m())
    );

    let mut t = Table::new(
        "sharded scale-out (modeled)",
        &["stacks", "makespan", "shard_speedup", "interconnect busy", "xfers"],
    );
    let mut last = None;
    for stacks in [1usize, 2, 4, 8] {
        let mut cfg = SystemConfig::default();
        cfg.mode = Mode::Estimate;
        cfg.num_stacks = stacks;
        let ex = Executor::new(cfg)?;
        let r = ex.run_sharded(&g)?;
        t.row(&[
            stacks.to_string(),
            fmt_time(r.shard_sim.seconds),
            fmt_ratio(r.shard_speedup()),
            fmt_time(r.shard_sim.interconnect_busy),
            r.n_xfers.to_string(),
        ]);
        last = Some(r);
    }
    t.print();

    // full per-stack report for the widest configuration
    if let Some(r) = last {
        println!();
        print!("{}", report::render_sharded(&r));
    }
    Ok(())
}
