//! Road-network scenario — the paper's urban-planning / transportation
//! motivation ([1], [2]): exact all-pairs travel times over a city-scale
//! road grid, then route queries between districts.
//!
//!     cargo run --release --example road_network

use rapid_graph::apsp::backend::NativeBackend;
use rapid_graph::apsp::recursive::{solve, SolveOptions};
use rapid_graph::apsp::validate::validate_sampled;
use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::coordinator::{executor::Executor, report};
use rapid_graph::graph::generators::{self, Weights};
use rapid_graph::util::table::Table;

fn main() -> rapid_graph::util::error::Result<()> {
    // 120 x 120 road grid: ~14.4k intersections, edge weight = minutes
    let (rows, cols) = (120usize, 120usize);
    let g = generators::grid2d(rows, cols, Weights::Uniform(0.5, 4.0), 7);
    println!(
        "road network: {} intersections, {} road segments\n",
        g.n(),
        g.m() / 2
    );

    let cfg = SystemConfig::default();
    let ex = Executor::new(cfg)?;
    let run = ex.run(&g)?;
    print!("{}", report::render(&run));

    // exact travel-time queries between districts (grid corners/center)
    let plan = ex.plan(&g);
    let backend = NativeBackend;
    let sol = solve(&g, &plan, Some(&backend), SolveOptions::default());
    let at = |r: usize, c: usize| r * cols + c;
    let spots = [
        ("NW depot", at(2, 3)),
        ("NE mall", at(4, cols - 5)),
        ("center hospital", at(rows / 2, cols / 2)),
        ("SW school", at(rows - 6, 5)),
        ("SE stadium", at(rows - 3, cols - 4)),
    ];
    let mut t = Table::new(
        "exact travel times between districts (minutes)",
        &["from \\ to", spots[0].0, spots[1].0, spots[2].0, spots[3].0, spots[4].0],
    );
    for (name, u) in &spots {
        let mut row = vec![name.to_string()];
        for (_, v) in &spots {
            row.push(format!("{:.1}", sol.query(*u, *v)));
        }
        t.row(&row);
    }
    t.print();

    let v = validate_sampled(&g, &sol, 16, 32, 1e-3, 5);
    println!(
        "validation: {} samples, {} mismatches -> {}",
        v.checked,
        v.mismatches,
        if v.ok(1e-3) { "EXACT" } else { "FAILED" }
    );
    assert!(v.ok(1e-3));
    Ok(())
}
