//! Scalability sweep (Fig. 9 style, runnable in seconds): size, degree
//! and topology sensitivity of the modeled RAPID-Graph hardware, using
//! estimate mode (identical trace/cost to functional mode).
//!
//!     cargo run --release --example scalability_sweep [--full]

use rapid_graph::bench::figures;
use rapid_graph::baselines::gpu;
use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::graph::generators::Topology;
use rapid_graph::util::table::{fmt_ratio, fmt_time};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = SystemConfig::default();

    let sizes: &[usize] = if full {
        &[1024, 4096, 16_384, 65_536, 262_144, 1_048_576]
    } else {
        &[1024, 4096, 16_384, 65_536]
    };
    let (t, series) = figures::fig9_size(&cfg, sizes);
    t.print();
    // linearity check: time per vertex across the sweep
    println!("modeled seconds per vertex (flat = linear scaling):");
    for (n, s) in &series {
        println!("  n={n:>9}: {:.3e} s/vertex", s / *n as f64);
    }
    println!();

    figures::fig9_degree(&cfg, 32_768, &[12.5, 25.25, 50.0]).print();

    let (t, secs) = figures::fig9_topology(
        &cfg,
        if full { 131_072 } else { 32_768 },
        &[Topology::Nws, Topology::OgbnProxy, Topology::Er],
    );
    t.print();
    println!(
        "topology penalty (ER vs NWS): {}",
        fmt_ratio(secs[2] / secs[0])
    );
    let h = gpu::h100().cost(32_768);
    println!(
        "(H100 reference at 32.8k: {} — topology-insensitive)",
        fmt_time(h.seconds)
    );
}
