//! Online serving scenario — the production shape the ROADMAP targets:
//! tenants submit APSP requests over time, and the coordinator admits
//! each one into the *running* schedule instead of draining the PIM
//! stack between batches. Arrivals are modeled-timeline stamps from
//! the admission config (never wall-clock), so the sweep is exactly
//! reproducible.
//!
//! The report shows each request's admission verdict, its modeled
//! admit-to-complete latency inside the live schedule, and the latency
//! the same request would see under the drain-and-rebatch baseline —
//! plus one oversized request that the memory guard turns away while
//! the pipeline keeps serving everyone else.
//!
//!     cargo run --release --example online_serving

use rapid_graph::coordinator::config::{Mode, SystemConfig};
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::coordinator::report;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::table::fmt_ratio;

fn main() -> rapid_graph::util::error::Result<()> {
    // (tenant, topology, n, degree) — the fourth request is far too
    // big for the configured stack memory and must be rejected cleanly
    let tenants: [(&str, Topology, usize, f64); 7] = [
        ("social-feed", Topology::OgbnProxy, 9_000, 12.0),
        ("rideshare", Topology::Grid, 6_000, 4.0),
        ("logistics", Topology::Nws, 5_000, 10.0),
        ("firehose-oversized", Topology::Er, 60_000, 16.0),
        ("fraud-graph", Topology::OgbnProxy, 7_000, 14.0),
        ("adhoc-analytics", Topology::Er, 4_000, 8.0),
        ("supply-chain", Topology::Nws, 8_000, 8.0),
    ];
    let graphs: Vec<_> = tenants
        .iter()
        .enumerate()
        .map(|(i, &(_, topo, n, degree))| {
            generators::generate(topo, n, degree, Weights::Uniform(1.0, 5.0), 200 + i as u64)
        })
        .collect();

    let mut cfg = SystemConfig::default();
    cfg.mode = Mode::Estimate; // cost model only: serving-scale graphs
    cfg.admission_queue_depth = 3;
    cfg.admission_interval = 2e-3; // 2 ms of modeled time between requests
    cfg.memory_limit_bytes = 2 << 30; // one stack's functional memory
    let ex = Executor::new(cfg)?;

    println!(
        "submitting {} tenant requests to the admission pipeline (2 ms stagger)...\n",
        graphs.len()
    );
    let a = ex.run_admission(&graphs)?;
    print!("{}", report::render_admission(&a));

    println!();
    for (i, r) in a.per_graph.iter().enumerate() {
        if r.verdict.admitted() {
            println!(
                "  {:<20} latency {} of drain baseline",
                tenants[i].0,
                fmt_ratio(r.latency / r.drain_latency.max(1e-30)),
            );
        } else {
            println!("  {:<20} turned away; later tenants unaffected", tenants[i].0);
        }
    }
    Ok(())
}
