//! Multi-tenant query serving scenario — the read-side production
//! shape: a road-style graph stays live (delta batches land between
//! query waves) while three tenants with different traffic mixes and
//! latency SLOs stream lookups against the published snapshot.
//!
//! The serve loop answers every query from the packed next-hop
//! snapshot — O(1) distances, O(path-len) reconstruction, no Dijkstra
//! anywhere — and hazard-pointer readers keep loading mid-repair, so
//! the report's torn_reads / swap-stall counters double as a live
//! proof that readers never block on the writer.
//!
//!     cargo run --release --example query_serving

use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::coordinator::report;
use rapid_graph::graph::generators::{self, Topology, Weights};
use rapid_graph::util::rng::Rng;
use std::fmt::Write as _;

fn main() -> rapid_graph::util::error::Result<()> {
    // a city-scale road proxy; degree 6 keeps ring edges 0-1 and 0-2
    // present by construction, so the mutation feed is deterministic
    let n = 1_500;
    let g = generators::generate(Topology::Nws, n, 6.0, Weights::Uniform(1.0, 5.0), 42);

    // three tenants, three traffic shapes:
    //   maps-app   — path-heavy point-to-point routing, tight SLO
    //   fleet-ops  — k-nearest depot scans + reachability audits
    //   analytics  — bulk distance probes, latency-tolerant
    let mut r = Rng::new(7);
    let mut script = String::new();
    for wave in 0..4 {
        let _ = writeln!(script, "# wave {wave}");
        for _ in 0..24 {
            let (u, v) = (r.gen_range(n), r.gen_range(n));
            let _ = writeln!(script, "path {u} {v} @maps-app");
        }
        for _ in 0..8 {
            let u = r.gen_range(n);
            let _ = writeln!(script, "knear {u} 12 @fleet-ops");
            let _ = writeln!(script, "reach {u} @fleet-ops");
        }
        for _ in 0..32 {
            let (u, v) = (r.gen_range(n), r.gen_range(n));
            let _ = writeln!(script, "dist {u} {v} @analytics");
        }
        script.push('\n'); // blank line: wave boundary = batch boundary
    }

    // the graph mutates underneath the tenants: one delta batch lands
    // (and swaps in a fresh snapshot) after each of the first 3 waves.
    // Degree 6 guarantees ring edges 0-1, 0-2, 0-3, so every delta
    // validates on any seed.
    let deltas = "reweight 0 1 0.25\n\ndelete 0 2\n\nreweight 0 3 9.5\n";

    let mut cfg = SystemConfig::default();
    cfg.tile_limit = 96;
    cfg.serve_slo_ms = 0.5; // shared 0.5 ms batch-drain SLO
    cfg.serve_panel_rows = 8;
    let ex = Executor::new(cfg)?;

    println!(
        "serving 4 query waves from 3 tenants against a live n={n} road proxy \
         (3 delta batches land mid-stream)...\n"
    );
    let s = ex.run_serve(&g, &script, Some(deltas))?;
    print!("{}", report::render_serve(&s));

    println!();
    for t in &s.tenants {
        if t.queries == 0 {
            continue;
        }
        let verdict = if t.slo_attained >= 0.99 { "met" } else { "MISSED" };
        println!(
            "  {:<10} SLO {verdict}: {:5.1}% of {} queries within 0.5 ms \
             (p99 {:.3e} s)",
            t.name,
            100.0 * t.slo_attained,
            t.queries,
            t.p99,
        );
    }
    if let Some(speedup) = s.path_speedup_vs_dijkstra() {
        println!(
            "\n  batched next-hop reconstruction vs per-query Dijkstra: {speedup:.0}x"
        );
    }
    Ok(())
}
