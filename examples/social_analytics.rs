//! Social / commercial analytics scenario — the paper's motivation [3],
//! [4]: distance-based centrality over a clustered social graph.
//!
//! Computes exact APSP on a community-structured network, then derives
//! closeness centrality for a set of candidate "influencer" vertices and
//! the distance distribution between communities — the "distance
//! backbone" style analysis of [4].
//!
//!     cargo run --release --example social_analytics

use rapid_graph::apsp::backend::NativeBackend;
use rapid_graph::apsp::recursive::{solve, SolveOptions};
use rapid_graph::coordinator::config::SystemConfig;
use rapid_graph::coordinator::executor::Executor;
use rapid_graph::graph::generators::{self, Weights};
use rapid_graph::util::table::{fmt_time, Table};

fn main() -> rapid_graph::util::error::Result<()> {
    let n = 12_000usize;
    let g = generators::ogbn_proxy_with(n, 18.0, 48, 512, 0.9, Weights::Uniform(1.0, 3.0), 11);
    println!(
        "social graph: {} users, {} ties, avg degree {:.1}",
        g.n(),
        g.m() / 2,
        g.avg_degree()
    );
    let cc = rapid_graph::graph::properties::clustering_coefficient(&g, 400, 3);
    println!("clustering coefficient (sampled): {cc:.3}\n");

    let cfg = SystemConfig::default();
    let ex = Executor::new(cfg)?;
    let plan = ex.plan(&g);
    let backend = NativeBackend;
    let t0 = std::time::Instant::now();
    let sol = solve(&g, &plan, Some(&backend), SolveOptions::default());
    println!("exact APSP in {}\n", fmt_time(t0.elapsed().as_secs_f64()));

    // closeness centrality for candidate influencers: C(u) = (n-1) / sum_v d(u,v)
    let mut rng = rapid_graph::util::rng::Rng::new(17);
    let candidates: Vec<usize> = (0..8).map(|_| rng.gen_range(n)).collect();
    let mut t = Table::new(
        "closeness centrality of candidate influencers",
        &["user", "reachable", "mean distance", "closeness"],
    );
    let mut best = (0usize, 0.0f64);
    for &u in &candidates {
        let mut sum = 0f64;
        let mut reach = 0usize;
        // sample columns for scale (exact per-pair queries)
        let samples = 600;
        for _ in 0..samples {
            let v = rng.gen_range(n);
            let d = sol.query(u, v);
            if d.is_finite() {
                sum += d as f64;
                reach += 1;
            }
        }
        let mean = sum / reach.max(1) as f64;
        let closeness = if mean > 0.0 { 1.0 / mean } else { 0.0 };
        if closeness > best.1 {
            best = (u, closeness);
        }
        t.row(&[
            format!("u{u}"),
            format!("{}/{samples}", reach),
            format!("{mean:.2}"),
            format!("{closeness:.4}"),
        ]);
    }
    t.print();
    println!("most central candidate: u{} (closeness {:.4})", best.0, best.1);

    // spot-check against Dijkstra
    let v = rapid_graph::apsp::validate::validate_sampled(&g, &sol, 12, 40, 1e-3, 23);
    assert!(v.ok(1e-3), "{v:?}");
    println!("validation: EXACT ({} samples)", v.checked);
    Ok(())
}
